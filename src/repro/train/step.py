"""Train-step factory: FPISA gradient aggregation at the data-parallel boundary.

Two execution shapes, selected by ``cfg.dp_boundary`` and the mesh:

* ``replica`` (dense/ssm/hybrid/vlm/audio): params are replicated over the
  replica axes (pod, data) and TP-sharded over 'model'. The whole
  grad-computation runs inside ``shard_map`` with the replica axes *manual*
  and 'model' *auto*; per-replica gradients are aggregated explicitly by the
  configured strategy (native float psum / SwitchML / FPISA integer planes /
  sequential switch semantics). This is the paper's architecture: workers
  compute full gradients, the "switch" (= the FPISA collective) aggregates.

* ``pod`` (MoE giants): experts and FSDP shards live on the (data, model)
  grid, so only the cross-pod hop carries replica-redundant gradients —
  exactly where an in-network aggregator physically sits. shard_map is manual
  over 'pod' only; in-pod reductions stay in XLA-native float, the cross-pod
  reduction is FPISA-integer (hierarchical aggregation, DESIGN.md §2).

On a single-pod mesh with ``pod`` boundary there is no replica axis left and
the step degrades to plain auto-jit with native reductions (recorded as such
in EXPERIMENTS.md).

The optimizer update runs *outside* the shard_map under automatic sharding so
ZeRO-1 ('data'-sharded m/v) resolves through XLA's partitioner.

Gradient aggregation is per-leaf by default; with ``agg.bucket_bytes`` set
(the ``--bucket-bytes`` launcher knob) the whole gradient pytree is streamed
through fixed-size block-aligned wire buckets with double-buffered dispatch
(core/bucketer.py) — bit-identical results, but the encode/decode overhead is
paid per bucket instead of per leaf and overlaps the in-flight collective.

Logical-worker mode (``logical_workers`` = W > 0) decouples the aggregation
group from the physical mesh for elastic fault tolerance: the global batch is
owned by W fixed logical workers (= switch ports); each mesh shard hosts
k = W / mesh_size of them, computes their gradients SEPARATELY (lax.map over
the local workers), and aggregates through the stacked integer-domain
collectives (core/allreduce.py stacked section). Because the wire shift is
derived from W and integer addition is associative, the aggregated gradient
— and the fixed-order loss reduction over the gathered (W,) per-worker loss
vector — are bit-identical on ANY mesh that divides W. That is what lets
runtime/controller.py resume training on a survivor mesh after a host death
with a trajectory equal, bit for bit, to the uninterrupted run.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.agg import AggConfig, Aggregator
from repro.optim import optimizers
from repro.sharding import rules


def _replica_axes(mesh: Mesh, cfg) -> tuple:
    if cfg.dp_boundary == "pod":
        return ("pod",) if "pod" in mesh.axis_names else ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_train_step(model, mesh: Mesh, agg: AggConfig, opt_cfg: optimizers.OptConfig,
                    global_batch: int, accum_steps: int = 1,
                    logical_workers: int = 0):
    """Returns step_fn(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum_steps`` > 1 splits the per-device batch into microbatches and
    scans over them, accumulating gradients in f32 — divides the remat
    activation live-set by the microbatch count at the cost of re-running the
    (already overlapped) backward collectives per microbatch.

    ``logical_workers`` > 0 selects logical-worker mode (module doc): W fixed
    aggregation ports independent of the mesh size; requires a non-native
    aggregation strategy, ``accum_steps == 1``, and a mesh whose replica
    extent divides both W and the global batch."""
    cfg = model.cfg
    boundary = _replica_axes(mesh, cfg)
    if logical_workers:
        if agg.strategy == "native" or not boundary:
            raise ValueError(
                "logical_workers needs an explicit aggregation boundary with "
                f"a non-native strategy (got strategy={agg.strategy!r}, "
                f"boundary={boundary})")
        if accum_steps != 1:
            raise ValueError("logical_workers is incompatible with accum_steps")
        repl = math.prod(mesh.shape[a] for a in boundary)
        if logical_workers % repl or global_batch % logical_workers:
            raise ValueError(
                f"logical_workers={logical_workers} must be a multiple of the "
                f"replica extent {repl} and divide global_batch={global_batch}")

    def grads_and_loss(params, batch):
        if accum_steps <= 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            return loss, grads

        def reshape(leaf):
            b = leaf.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return leaf.reshape(accum_steps, b // accum_steps, *leaf.shape[1:])

        micro = jax.tree.map(reshape, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            acc, loss_acc = carry
            loss, grads = jax.value_and_grad(model.loss)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_acc + loss), None

        (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), micro)
        inv = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * inv, grads)
        return loss * inv, grads

    if boundary and agg.strategy != "native":
        batch_axes = rules.batch_axes(mesh, global_batch)
        manual_batch_axes = tuple(a for a in batch_axes if a in boundary)
        # the ONE facade instance for this step: strategy/backend resolution
        # and capability validation happen here, before anything is traced
        aggregator = Aggregator(agg, boundary, stacked=bool(logical_workers))

        if logical_workers:
            def sharded_grads(params, batch):
                # this shard hosts k = W / replica_extent logical workers,
                # each owning a fixed global-batch slice (contiguous: shard d
                # hosts workers [d*k, (d+1)*k) — matches _gather_logical)
                repl = math.prod(compat.axis_size(a) for a in boundary)
                k = logical_workers // repl

                def split(leaf):
                    b = leaf.shape[0]
                    assert b % k == 0, (b, k)
                    return leaf.reshape(k, b // k, *leaf.shape[1:])

                losses, grads = jax.lax.map(
                    lambda mb: jax.value_and_grad(model.loss)(params, mb),
                    jax.tree.map(split, batch))
                # stacked integer-domain aggregation over (worker, mesh) —
                # bit-identical on any mesh dividing W (core/allreduce.py)
                grads = aggregator.allreduce_tree(grads)
                # fixed-order loss reduction: the gathered (W,) vector has the
                # same shape and order on every mesh. The sum MUST be a scan —
                # a jnp.sum here gets pattern-matched into a cross-device
                # all-reduce whose grouping follows the mesh size, and the
                # scalar stops being bit-reproducible across re-meshes.
                gathered = jax.lax.all_gather(losses, boundary).reshape(-1)
                loss, _ = jax.lax.scan(
                    lambda c, v: (c + v, None), jnp.float32(0), gathered)
                return loss / logical_workers, grads
        else:
            def sharded_grads(params, batch):
                loss, grads = grads_and_loss(params, batch)
                # per-leaf or bucketed per agg.bucket_bytes (core/bucketer.py)
                grads = aggregator.allreduce_tree(grads)
                loss = jax.lax.pmean(loss, boundary)
                return loss, grads

        def batch_spec(leaf):
            return P(*( [manual_batch_axes if manual_batch_axes else None]
                       + [None] * (leaf.ndim - 1)))

        def apply_grads(params, batch):
            in_specs = (
                jax.tree.map(lambda _: P(), params),
                jax.tree.map(batch_spec, batch),
            )
            return compat.shard_map(
                sharded_grads,
                mesh=mesh,
                in_specs=in_specs,
                out_specs=(P(), jax.tree.map(lambda _: P(), params)),
                axis_names=set(boundary),
                check_vma=False,
            )(params, batch)
    else:
        def apply_grads(params, batch):
            loss, grads = grads_and_loss(params, batch)
            return loss, grads

    def train_step(params, opt_state, batch):
        loss, grads = apply_grads(params, batch)
        params, opt_state, metrics = optimizers.update(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_serve_steps(model, mesh: Mesh):
    """(prefill_fn, decode_fn) — plain auto-sharded jit functions."""

    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache)

    def decode(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    return prefill, decode
