"""Train-step factory: FPISA gradient aggregation at the data-parallel boundary.

Two execution shapes, selected by ``cfg.dp_boundary`` and the mesh:

* ``replica`` (dense/ssm/hybrid/vlm/audio): params are replicated over the
  replica axes (pod, data) and TP-sharded over 'model'. The whole
  grad-computation runs inside ``shard_map`` with the replica axes *manual*
  and 'model' *auto*; per-replica gradients are aggregated explicitly by the
  configured strategy (native float psum / SwitchML / FPISA integer planes /
  sequential switch semantics). This is the paper's architecture: workers
  compute full gradients, the "switch" (= the FPISA collective) aggregates.

* ``pod`` (MoE giants): experts and FSDP shards live on the (data, model)
  grid, so only the cross-pod hop carries replica-redundant gradients —
  exactly where an in-network aggregator physically sits. shard_map is manual
  over 'pod' only; in-pod reductions stay in XLA-native float, the cross-pod
  reduction is FPISA-integer (hierarchical aggregation, DESIGN.md §2).

On a single-pod mesh with ``pod`` boundary there is no replica axis left and
the step degrades to plain auto-jit with native reductions (recorded as such
in EXPERIMENTS.md).

The optimizer update runs *outside* the shard_map under automatic sharding so
ZeRO-1 ('data'-sharded m/v) resolves through XLA's partitioner.

Gradient aggregation is per-leaf by default; with ``agg.bucket_bytes`` set
(the ``--bucket-bytes`` launcher knob) the whole gradient pytree is streamed
through fixed-size block-aligned wire buckets with double-buffered dispatch
(core/bucketer.py) — bit-identical results, but the encode/decode overhead is
paid per bucket instead of per leaf and overlaps the in-flight collective.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.allreduce import AggConfig, allreduce_tree
from repro.optim import optimizers
from repro.sharding import rules


def _replica_axes(mesh: Mesh, cfg) -> tuple:
    if cfg.dp_boundary == "pod":
        return ("pod",) if "pod" in mesh.axis_names else ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_train_step(model, mesh: Mesh, agg: AggConfig, opt_cfg: optimizers.OptConfig,
                    global_batch: int, accum_steps: int = 1):
    """Returns step_fn(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum_steps`` > 1 splits the per-device batch into microbatches and
    scans over them, accumulating gradients in f32 — divides the remat
    activation live-set by the microbatch count at the cost of re-running the
    (already overlapped) backward collectives per microbatch."""
    cfg = model.cfg
    boundary = _replica_axes(mesh, cfg)

    def grads_and_loss(params, batch):
        if accum_steps <= 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            return loss, grads

        def reshape(leaf):
            b = leaf.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return leaf.reshape(accum_steps, b // accum_steps, *leaf.shape[1:])

        micro = jax.tree.map(reshape, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            acc, loss_acc = carry
            loss, grads = jax.value_and_grad(model.loss)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_acc + loss), None

        (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), micro)
        inv = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * inv, grads)
        return loss * inv, grads

    if boundary and agg.strategy != "native":
        batch_axes = rules.batch_axes(mesh, global_batch)
        manual_batch_axes = tuple(a for a in batch_axes if a in boundary)

        def sharded_grads(params, batch):
            loss, grads = grads_and_loss(params, batch)
            # per-leaf or bucketed per agg.bucket_bytes (core/bucketer.py)
            grads = allreduce_tree(grads, boundary, agg)
            loss = jax.lax.pmean(loss, boundary)
            return loss, grads

        def batch_spec(leaf):
            return P(*( [manual_batch_axes if manual_batch_axes else None]
                       + [None] * (leaf.ndim - 1)))

        def apply_grads(params, batch):
            in_specs = (
                jax.tree.map(lambda _: P(), params),
                jax.tree.map(batch_spec, batch),
            )
            return compat.shard_map(
                sharded_grads,
                mesh=mesh,
                in_specs=in_specs,
                out_specs=(P(), jax.tree.map(lambda _: P(), params)),
                axis_names=set(boundary),
                check_vma=False,
            )(params, batch)
    else:
        def apply_grads(params, batch):
            loss, grads = grads_and_loss(params, batch)
            return loss, grads

    def train_step(params, opt_state, batch):
        loss, grads = apply_grads(params, batch)
        params, opt_state, metrics = optimizers.update(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_serve_steps(model, mesh: Mesh):
    """(prefill_fn, decode_fn) — plain auto-sharded jit functions."""

    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache)

    def decode(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    return prefill, decode
