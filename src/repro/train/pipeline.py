"""Pipeline parallelism: GPipe-style microbatched stage loop via shard_map +
collective_permute.

Stages live on the ``pp`` mesh axis (mapped onto 'pod' for the production
mesh, or a dedicated axis on test meshes). The stacked layer parameters
(L, ...) are split into ``n_stages`` contiguous chunks along L and sharded so
each stage group holds only its chunk. The schedule runs m + n - 1 ticks for
m microbatches; activations flow stage→stage via ppermute. Because ppermute
is differentiable (its transpose is the reverse permute), ``jax.grad``
through this forward yields the reverse-schedule pipelined backward for free
— no hand-written bubble management for the backward pass.

Scope: dense/vlm-family blocks (the families that benefit from PP depth);
embedding and head are computed on every stage (replicated, cheap) with the
pipeline carrying the residual stream only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.models import transformer as T
from repro.models.layers import embed, rms_norm


def split_stages(params, n_stages: int):
    """Reshape stacked layer params (L, ...) -> (n_stages, L/n_stages, ...)."""
    def one(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])

    out = dict(params)
    out["layers"] = jax.tree.map(one, params["layers"])
    return out


def _stage_fn(stage_layers, x, cfg, positions):
    def body(carry, lp):
        y, _ = T._dense_block(lp, carry, cfg, positions)
        return y, None

    y, _ = jax.lax.scan(jax.checkpoint(body), x, stage_layers)
    return y


def pipeline_forward(params, batch, cfg, *, stage_axis: str, n_micro: int):
    """Runs inside shard_map with ``stage_axis`` manual. params['layers'] is
    the LOCAL stage chunk (L/n_stages, ...); other params replicated.
    Returns logits for the full batch (valid on the last stage, broadcast to
    all stages for loss uniformity)."""
    n = compat.axis_size(stage_axis)
    sid = lax.axis_index(stage_axis)
    toks = batch["tokens"]
    b, s = toks.shape
    assert b % n_micro == 0
    mb = b // n_micro

    x_full = embed(params["embed"], toks).astype(jnp.dtype(cfg.activation_dtype))
    micro = x_full.reshape(n_micro, mb, s, -1)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (mb, s))

    fwd = functools.partial(_stage_fn, params["layers"], cfg=cfg, positions=positions)

    def tick(carry, t):
        stream, outputs = carry  # stream: (mb, s, d) activation entering this stage
        # stage 0 injects microbatch t (when valid); others use the stream
        inject = jnp.where(t < n_micro, t, 0)
        x_in = jnp.where(sid == 0, micro[inject], stream)
        y = fwd(x=x_in)
        # forward the result to the next stage
        nxt = lax.ppermute(y, stage_axis, [(i, i + 1) for i in range(n - 1)])
        # last stage banks its result for microbatch t - (n - 1)
        out_idx = jnp.clip(t - (n - 1), 0, n_micro - 1)
        bank = (t >= n - 1) & (sid == n - 1)
        outputs = jax.lax.dynamic_update_index_in_dim(
            outputs, jnp.where(bank, y, outputs[out_idx]), out_idx, axis=0
        )
        return (nxt, outputs), None

    stream0 = jnp.zeros_like(micro[0])
    outputs0 = jnp.zeros_like(micro)
    (_, outputs), _ = jax.lax.scan(
        tick, (stream0, outputs0), jnp.arange(n_micro + n - 1)
    )
    # broadcast last stage's outputs to all stages (psum over one-hot holder)
    mask = (sid == n - 1).astype(outputs.dtype)
    # exactly one stage is nonzero, so the sum has a single term and no
    # ordering sensitivity — not a gradient-path reduce
    # repro-lint: disable=bit-identity
    outputs = lax.psum(outputs * mask, stage_axis)

    x = outputs.reshape(b, s, -1)
    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]["w"]
    return jnp.einsum("bsd,dv->bsv", x, w)


def make_pp_loss(cfg, mesh: Mesh, stage_axis: str = "pod", n_micro: int = 4):
    """Returns loss_fn(params_staged, batch) running the pipeline under
    shard_map (stage axis manual, everything else auto)."""

    def loss_inner(params, batch):
        # shard_map keeps the sharded stage axis with local size 1 — squeeze
        # to get this stage's (L/n_stages, ...) chunk
        params = dict(params) | {
            "layers": jax.tree.map(lambda a: a[0], params["layers"])
        }
        logits = pipeline_forward(params, batch, cfg, stage_axis=stage_axis,
                                  n_micro=n_micro)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        tgt = batch["tokens"][:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        return nll.mean()

    def loss(params_staged, batch):
        in_specs = (
            jax.tree.map(lambda _: P(), params_staged) | {
                "layers": jax.tree.map(lambda _: P(stage_axis), params_staged["layers"])
            },
            jax.tree.map(lambda _: P(), batch),
        )
        return compat.shard_map(
            loss_inner, mesh=mesh, in_specs=in_specs, out_specs=P(),
            axis_names={stage_axis}, check_vma=False,
        )(params_staged, batch)

    return loss
