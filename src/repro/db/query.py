"""Distributed query processing with in-switch FPISA operators (paper Sec. 6).

Reproduces the Cheetah [SIGMOD'20] / NETACCEL [CIDR'19] acceleration patterns
with FP32 data, which the original systems cannot handle:

* in-switch PRUNING (Top-N, group-by-having): the switch keeps a running
  threshold register in FPISA planes and drops rows that cannot affect the
  final result; only survivors reach the master. FP comparison is FPISA
  subtraction + sign test (Sec. 2.2) — integer-only.
* in-switch AGGREGATION (group-by sum): per-group FPISA accumulator slots
  (full FPISA add — the paper notes query aggregation needs the RSAW
  hardware extension rather than the FPISA-A approximation, Sec. 6.1).

The "workers -> switch -> master" dataflow is emulated faithfully: workers
stream row *batches*, the switch side runs as the jitted batched kernels in
``repro/switchsim/query.py`` (one dispatch per batch — the per-row Python
loops are gone), the master does final exact processing on survivors.
Benchmarks report rows-pruned and speedup vs a "Spark-like" full-scan
baseline (fig13).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core import fpisa
from repro.switchsim import query as swq
from repro.switchsim.dataplane import _pow2ceil


def _cmp_planes(a: fpisa.Planes, b: fpisa.Planes) -> np.ndarray:
    """FPISA comparison a > b via subtraction sign (integer-only)."""
    neg_b = fpisa.Planes(exp=b.exp, man=-jnp.asarray(b.man))
    diff, _ = fpisa.fpisa_add_full(a, neg_b)
    return np.asarray(diff.man) > 0


@dataclasses.dataclass
class SwitchStats:
    rows_in: int = 0
    rows_out: int = 0

    @property
    def prune_rate(self) -> float:
        return 1.0 - self.rows_out / max(self.rows_in, 1)


class TopNPruner:
    """In-switch Top-N on an FP32 column. The switch keeps the N-th best value
    seen so far in FPISA registers; rows below it are dropped (Cheetah's
    pruning abstraction) — one ``switchsim.query.topn_keep`` dispatch per row
    batch. The master exactly sorts the survivors."""

    def __init__(self, n: int):
        self.n = n
        self.stats = SwitchStats()

    def run(self, values: np.ndarray, batch: int = 256) -> np.ndarray:
        """values: worker-streamed FP32 column. Returns indices of survivors."""
        values = np.asarray(values, np.float32)
        thresh = None  # FPISA planes of the current N-th best
        heap = np.empty(0, np.float32)  # switch-side shadow of the N best
        survivors = []
        for lo in range(0, len(values), batch):
            chunk = values[lo : lo + batch]
            self.stats.rows_in += len(chunk)
            if thresh is None:
                keep = np.ones(len(chunk), bool)
            else:
                keep = np.asarray(swq.topn_keep(
                    jnp.asarray(chunk), thresh[0], thresh[1]))
            idx = np.nonzero(keep)[0] + lo
            survivors.extend(idx.tolist())
            self.stats.rows_out += int(keep.sum())
            heap = np.concatenate([heap, values[idx]])
            if len(heap) >= self.n:
                heap = np.partition(heap, -self.n)[-self.n :]
                t = fpisa.encode(jnp.float32(heap.min()))
                thresh = (t.exp, t.man)
        return np.asarray(survivors, np.int64)


class GroupBySum:
    """In-switch hash aggregation: value column summed per group key in FPISA
    accumulator slots (full-FPISA add). Only per-group aggregates leave the
    switch — the row stream itself is consumed in-network.

    Rows are streamed through ``switchsim.query.groupby_ingest``: batches are
    sorted by key (stable, preserving packet order within a key) and applied
    with per-slot sequential semantics in a handful of vectorized rounds."""

    # The paper's headroom analysis (Sec. 3.3): 7 headroom bits cover ~128
    # same-scale adds before the int32 register can overflow. Long-running
    # group-by slots therefore FLUSH periodically: renormalize + re-encode the
    # register (in deployment: emit a partial aggregate to the master and
    # reset the slot). 64 keeps a 2x safety margin. The flush counter lives in
    # the slot and persists across batches.
    FLUSH_EVERY = 64

    def __init__(self, num_slots: int, variant: str = "full"):
        self.num_slots = num_slots
        self.variant = variant
        self.exp = np.zeros(num_slots, np.int32)
        self.man = np.zeros(num_slots, np.int32)
        self.since = np.zeros(num_slots, np.int32)
        self.stats = SwitchStats()

    def run(self, keys: np.ndarray, values: np.ndarray, batch: int = 65536) -> dict:
        keys = np.asarray(keys)
        assert keys.max() < self.num_slots, "hash table sized for distinct keys"
        values = np.asarray(values, np.float32)
        self.stats.rows_in += len(keys)
        # stream rows through the pipeline in batches, sorted by key within
        # the batch (stable: per-key packet order is the stream order)
        exp, man, since = (jnp.asarray(self.exp), jnp.asarray(self.man),
                           jnp.asarray(self.since))
        for lo in range(0, len(keys), batch):
            order = np.argsort(keys[lo : lo + batch], kind="stable")
            k = keys[lo : lo + batch][order].astype(np.int32)
            v = values[lo : lo + batch][order]
            # rounds >= the max per-key multiplicity: everything lands in one
            # dispatch; pad to a power of two to bound jit re-specialization
            rounds = _pow2ceil(int(np.bincount(k).max()))
            bp = _pow2ceil(len(k))
            vmask = np.arange(bp) < len(k)
            exp, man, since, deferred = swq.groupby_ingest(
                exp, man, since,
                jnp.asarray(np.pad(k, (0, bp - len(k)))),
                jnp.asarray(np.pad(v, (0, bp - len(k)))),
                jnp.asarray(vmask),
                num_slots=self.num_slots, rounds=rounds, variant=self.variant,
                flush_every=self.FLUSH_EVERY)
            assert not bool(np.asarray(deferred).any())
        self.exp, self.man, self.since = (np.asarray(exp), np.asarray(man),
                                          np.asarray(since))
        self.stats.rows_out += len(np.unique(keys))
        out = fpisa.renormalize(fpisa.Planes(jnp.asarray(self.exp), jnp.asarray(self.man)))
        return {int(k): float(out[k]) for k in np.unique(keys)}


class StreamedGroupBySum:
    """Group-by sum riding a (possibly multi-tenant) switch *dataplane* as a
    query stream (DESIGN.md §10): each row batch collapses worker-side into
    one packet carrying the batch's per-group partial sums, the packets
    contend for aggregation slots like any other tenant's traffic (a
    single-port job: one chunk per row batch), and the master folds the
    delivered partials into totals. This is the "query stream shares the
    switch with training jobs" scenario — drive :meth:`vectors` through
    ``switchsim.tenancy.run_multitenant`` as one of its jobs and hand the
    returned flat vector to :meth:`finalize`.

    Accuracy note: the switch round-trips each partial through FPISA
    encode/decode (a W=1 slot completes on its single packet), so totals
    carry one quantization per batch — ``benchmarks/fig_contention.py``
    reports the max relative error vs ``spark_like_groupby``.
    """

    def __init__(self, num_groups: int, elems_per_packet: int = 256):
        assert num_groups <= elems_per_packet, \
            "per-batch partials must fit one packet"
        self.num_groups = num_groups
        self.elems_per_packet = elems_per_packet
        self.stats = SwitchStats()

    def vectors(self, keys: np.ndarray, values: np.ndarray,
                batch: int = 4096) -> np.ndarray:
        """(1, nbatches * elems_per_packet) worker vector: row batch b's
        per-group partial sums occupy chunk b's first ``num_groups`` lanes."""
        keys = np.asarray(keys)
        values = np.asarray(values, np.float32)
        assert keys.max() < self.num_groups, "hash table sized for distinct keys"
        self.stats.rows_in += len(keys)
        parts = []
        for lo in range(0, len(keys), batch):
            part = np.bincount(
                keys[lo : lo + batch],
                weights=values[lo : lo + batch].astype(np.float64),
                minlength=self.num_groups).astype(np.float32)
            parts.append(np.pad(part, (0, self.elems_per_packet - self.num_groups)))
        self.stats.rows_out += len(parts)  # one partial packet per batch
        return np.concatenate(parts)[None, :]

    def finalize(self, flat: np.ndarray) -> dict:
        """Fold the aggregated flat vector (as returned for this job by
        ``run_multitenant``) back into {group: total}."""
        part = np.asarray(flat).reshape(-1, self.elems_per_packet)
        totals = part[:, : self.num_groups].astype(np.float64).sum(axis=0)
        return {int(k): float(totals[k]) for k in range(self.num_groups)}


def spark_like_topn(values: np.ndarray, n: int) -> np.ndarray:
    """Full-scan baseline: every row is shipped to the master and sorted."""
    return np.sort(values)[::-1][:n]


def spark_like_groupby(keys: np.ndarray, values: np.ndarray) -> dict:
    out = {}
    for k in np.unique(keys):
        out[int(k)] = float(values[keys == k].astype(np.float64).sum())
    return out
