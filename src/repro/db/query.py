"""Distributed query processing with in-switch FPISA operators (paper Sec. 6).

Reproduces the Cheetah [SIGMOD'20] / NETACCEL [CIDR'19] acceleration patterns
with FP32 data, which the original systems cannot handle:

* in-switch PRUNING (Top-N, group-by-having): the switch keeps a running
  threshold register in FPISA planes and drops rows that cannot affect the
  final result; only survivors reach the master. FP comparison is FPISA
  subtraction + sign test (Sec. 2.2) — integer-only.
* in-switch AGGREGATION (group-by sum): per-group FPISA accumulator slots
  (full FPISA add — the paper notes query aggregation needs the RSAW
  hardware extension rather than the FPISA-A approximation, Sec. 6.1).

The "workers -> switch -> master" dataflow is emulated faithfully: workers
stream row packets, the switch emulator applies the operator, the master does
final exact processing on survivors. Benchmarks report rows-pruned and
speedup vs a "Spark-like" full-scan baseline (fig13).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core import fpisa


def _cmp_planes(a: fpisa.Planes, b: fpisa.Planes) -> np.ndarray:
    """FPISA comparison a > b via subtraction sign (integer-only)."""
    neg_b = fpisa.Planes(exp=b.exp, man=-jnp.asarray(b.man))
    diff, _ = fpisa.fpisa_add_full(a, neg_b)
    return np.asarray(diff.man) > 0


@dataclasses.dataclass
class SwitchStats:
    rows_in: int = 0
    rows_out: int = 0

    @property
    def prune_rate(self) -> float:
        return 1.0 - self.rows_out / max(self.rows_in, 1)


class TopNPruner:
    """In-switch Top-N on an FP32 column. The switch keeps the N-th best value
    seen so far in FPISA registers; rows below it are dropped (Cheetah's
    pruning abstraction). The master exactly sorts the survivors."""

    def __init__(self, n: int):
        self.n = n
        self.stats = SwitchStats()

    def run(self, values: np.ndarray, batch: int = 256) -> np.ndarray:
        """values: worker-streamed FP32 column. Returns indices of survivors."""
        thresh = None  # FPISA planes of the current N-th best
        heap: list = []  # switch-side shadow of the N best (bounded memory)
        survivors = []
        for lo in range(0, len(values), batch):
            chunk = values[lo : lo + batch]
            self.stats.rows_in += len(chunk)
            if thresh is None:
                keep = np.ones(len(chunk), bool)
            else:
                planes = fpisa.encode(jnp.asarray(chunk, jnp.float32))
                tplanes = fpisa.Planes(
                    exp=jnp.broadcast_to(thresh.exp, planes.exp.shape),
                    man=jnp.broadcast_to(thresh.man, planes.man.shape),
                )
                keep = _cmp_planes(planes, tplanes)
            idx = np.nonzero(keep)[0] + lo
            survivors.extend(idx.tolist())
            self.stats.rows_out += int(keep.sum())
            heap.extend(values[idx].tolist())
            heap = sorted(heap, reverse=True)[: self.n]
            if len(heap) == self.n:
                t = fpisa.encode(jnp.float32(heap[-1]))
                thresh = fpisa.Planes(exp=t.exp, man=t.man)
        return np.asarray(survivors, np.int64)


class GroupBySum:
    """In-switch hash aggregation: value column summed per group key in FPISA
    accumulator slots (full-FPISA add). Only per-group aggregates leave the
    switch — the row stream itself is consumed in-network."""

    def __init__(self, num_slots: int, variant: str = "full"):
        self.num_slots = num_slots
        self.variant = variant
        self.exp = np.zeros(num_slots, np.int32)
        self.man = np.zeros(num_slots, np.int32)
        self.stats = SwitchStats()

    # The paper's headroom analysis (Sec. 3.3): 7 headroom bits cover ~128
    # same-scale adds before the int32 register can overflow. Long-running
    # group-by slots therefore FLUSH periodically: renormalize + re-encode the
    # register (in deployment: emit a partial aggregate to the master and
    # reset the slot). 64 keeps a 2x safety margin.
    FLUSH_EVERY = 64

    def run(self, keys: np.ndarray, values: np.ndarray) -> dict:
        assert keys.max() < self.num_slots, "hash table sized for distinct keys"
        self.stats.rows_in += len(keys)
        add = fpisa.fpisa_add_full if self.variant == "full" else fpisa.fpisa_a_add
        # stream rows through the pipeline in packet order
        order = np.argsort(keys, kind="stable")
        for lo in range(0, len(order), 4096):
            sel = order[lo : lo + 4096]
            planes = fpisa.encode(jnp.asarray(values[sel], jnp.float32))
            k = keys[sel]
            exp_j = jnp.asarray(self.exp)
            man_j = jnp.asarray(self.man)
            # sequential semantics per slot preserved because rows are sorted
            # by key within the batch and slots are disjoint across segments
            uk, starts = np.unique(k, return_index=True)
            for i, key in enumerate(uk):
                seg = slice(starts[i], starts[i + 1] if i + 1 < len(uk) else len(sel))
                acc = fpisa.Planes(exp_j[key][None], man_j[key][None])
                vals = fpisa.Planes(planes.exp[seg], planes.man[seg])
                since_flush = 0
                for j in range(vals.exp.shape[0]):
                    acc, _ = add(acc, fpisa.Planes(vals.exp[j][None], vals.man[j][None]))
                    since_flush += 1
                    if since_flush >= self.FLUSH_EVERY:
                        acc = fpisa.encode(fpisa.renormalize(acc))
                        since_flush = 0
                self.exp[key] = int(acc.exp[0])
                self.man[key] = int(acc.man[0])
        self.stats.rows_out += len(np.unique(keys))
        out = fpisa.renormalize(
            fpisa.Planes(jnp.asarray(self.exp), jnp.asarray(self.man))
        )
        return {int(k): float(out[k]) for k in np.unique(keys)}


def spark_like_topn(values: np.ndarray, n: int) -> np.ndarray:
    """Full-scan baseline: every row is shipped to the master and sorted."""
    return np.sort(values)[::-1][:n]


def spark_like_groupby(keys: np.ndarray, values: np.ndarray) -> dict:
    out = {}
    for k in np.unique(keys):
        out[int(k)] = float(values[keys == k].astype(np.float64).sum())
    return out
