"""Bucket-plan search: sweep candidate ``bucket_bytes`` against the cost
model and surface the winner as ``--bucket-bytes auto``.

The search scores each candidate by building the EXACT static plan the
bucketer would build (``core.bucketer.make_plan`` — same block alignment,
same dtype grouping, same dispatch ordering) and pushing its bucket sizes
through :meth:`CostModel.pipeline_time`. Candidate 0 (the per-leaf path)
is scored over the block-padded leaf sizes, so auto can fall back to
per-leaf when the model says bucketing would lose. Orderings are fixed by
the bucketer's reverse-autograd contract; the sweep varies only the cut.

``auto_bucket_bytes`` is the ``AggConfig.from_args`` hook: it fits the
model from the trace named by ``--autotune-trace`` / $REPRO_AUTOTUNE_TRACE
and, lacking any trace, falls back LOUDLY (a ``UserWarning``) to the
measured-good fig11 default rather than guessing silently.
"""
from __future__ import annotations

import math
import os
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.autotune import costmodel
from repro.core.bucketer import make_plan

# fig11's measured-good plan (BENCH_fig11: 4 MiB buckets beat per-leaf by
# ~1.1x at full size) — the loud-fallback choice when no trace exists
DEFAULT_AUTO_BUCKET_BYTES = 4 << 20

TRACE_ENV = "REPRO_AUTOTUNE_TRACE"

# synthetic reference workload for the CLI path, where the gradient tree is
# not known yet at flag-parsing time: a ragged fp32 parameter list in the
# fig11 shape (big ffn / medium attn / tiny non-block-multiple norm per
# layer) totalling ~16M elems; DESIGN.md §13 discusses the proxy error
_REFERENCE_ELEMS = 1 << 24
_REFERENCE_LAYER = (16384, 4096, 777)


def _ceil_to(n: int, q: int) -> int:
    return -(-n // q) * q


def candidate_bucket_bytes(total_bytes: int, *, lo: int = 1 << 16,
                           hi: int = 32 << 20) -> tuple[int, ...]:
    """Power-of-two sweep from ``lo`` up to the workload size (capped at
    ``hi``), plus 0 for the per-leaf path."""
    cands, b = [0], lo
    top = min(hi, max(_ceil_to(total_bytes, lo), lo))
    while b < top:
        cands.append(b)
        b <<= 1
    cands.append(top)
    return tuple(dict.fromkeys(cands))


def plan_sizes(leaves: Sequence, *, block: int,
               bucket_bytes: int) -> list[int]:
    """Bucket element counts, in dispatch order, of the plan this
    ``bucket_bytes`` would produce (0 = per-leaf: each float leaf is its own
    'bucket', block-padded, in the same reverse-flatten dispatch order)."""
    if bucket_bytes:
        plan = make_plan(leaves, block=block, bucket_bytes=bucket_bytes)
        return [b.elems for b in plan.buckets]
    sizes = []
    for leaf in reversed(list(leaves)):
        n = int(math.prod(leaf.shape)) if leaf.shape else 1
        if n and jnp.issubdtype(jnp.dtype(leaf.dtype), jnp.floating):
            sizes.append(_ceil_to(n, block))
    return sizes


def predict_tree_time(model: costmodel.CostModel, leaves: Sequence, *,
                      block: int, bucket_bytes: int) -> float:
    return model.pipeline_time(
        plan_sizes(leaves, block=block, bucket_bytes=bucket_bytes))


def choose_bucket_bytes(model: costmodel.CostModel, leaves: Sequence, *,
                        block: int,
                        candidates: Sequence[int] | None = None
                        ) -> tuple[int, dict[int, float]]:
    """Sweep candidates; returns (best bucket_bytes, {candidate: predicted
    seconds}). Ties break toward the smaller plan (less transient memory)."""
    if candidates is None:
        total = sum(
            int(math.prod(l.shape) or 1) * jnp.dtype(l.dtype).itemsize
            for l in leaves)
        candidates = candidate_bucket_bytes(total)
    scores = {
        int(c): predict_tree_time(model, leaves, block=block,
                                  bucket_bytes=int(c))
        for c in candidates}
    best = min(sorted(scores), key=lambda c: scores[c])
    return best, scores


def reference_leaves(total_elems: int = _REFERENCE_ELEMS):
    leaves, total = [], 0
    while total < total_elems:
        for n in _REFERENCE_LAYER:
            leaves.append(jax.ShapeDtypeStruct((n,), jnp.float32))
            total += n
    return leaves


def auto_bucket_bytes(*, trace_path: str | None = None, block: int = 256,
                      leaves: Sequence | None = None) -> int:
    """Resolve ``--bucket-bytes auto`` to a concrete byte count.

    Fits the cost model from ``trace_path`` (or $REPRO_AUTOTUNE_TRACE) and
    sweeps the candidate plans for ``leaves`` (or the synthetic reference
    workload when the tree is not known at flag time). With no trace
    available this warns loudly and returns the measured-good default —
    auto must never silently degrade into an arbitrary guess."""
    path = trace_path or os.environ.get(TRACE_ENV)
    if not path or not os.path.exists(path):
        warnings.warn(
            f"--bucket-bytes auto: no autotune trace "
            f"({'missing file ' + repr(path) if path else 'none given via --autotune-trace or $' + TRACE_ENV}); "
            f"falling back to the measured default "
            f"{DEFAULT_AUTO_BUCKET_BYTES} bytes. Record one with "
            f"--trace-out or repro.autotune.profile.profile_phases.",
            UserWarning, stacklevel=2)
        return DEFAULT_AUTO_BUCKET_BYTES
    model = costmodel.fit_from_jsonl(path)
    if leaves is None:
        leaves = reference_leaves()
    best, _ = choose_bucket_bytes(model, leaves, block=block)
    return best
