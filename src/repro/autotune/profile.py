"""Phase replay profiler: measure encode/collective/finish at probe sizes.

The cost model (``costmodel.py``) fits per-phase time as a function of
bucket size; this module produces those measurements by replaying the
strategy's split-phase pipeline — the SAME registry hooks the bucketer
dispatches through (``StrategySpec.flat_phases``) — as three separately
jitted ``shard_map`` programs, each timed under a synced tracer span::

    autotune.probe {phase: encode,     elems: n, synced: True}
    autotune.probe {phase: collective, elems: n, synced: True}
    autotune.probe {phase: finish,     elems: n, synced: True}

Because each phase is dispatched and blocked on individually, the spans
measure real steady-state device time per phase (warmup iterations eat the
compile), not trace-time — the attribution rule the tracer's sync boundary
exists for. The split does lose cross-phase fusion XLA might apply inside
one jit; that bias is part of the "when replay lies" contract in
DESIGN.md §13.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import trace as _trace
from repro.core.agg import AggConfig, get_strategy, resolve_backend


def probe_sizes(*, block: int = 256, n_probes: int = 6,
                max_elems: int = 1 << 20) -> tuple[int, ...]:
    """Geometric block-multiple probe grid from one block up to
    ``max_elems`` — wide enough that the fit separates fixed from
    per-element cost."""
    sizes, n = [], block
    while n <= max_elems and len(sizes) < n_probes:
        sizes.append(n)
        n *= 4
    return tuple(sizes)


def profile_phases(cfg: AggConfig | None = None, *,
                   sizes: Sequence[int] | None = None,
                   axes: Sequence[str] = ("data",),
                   iters: int = 3, warmup: int = 1, seed: int = 0,
                   tracer: "_trace.Tracer | None" = None) -> list[dict]:
    """Replay the flat split-phase pipeline at each probe size; returns the
    recorded span dicts (also left on the tracer used).

    Spans land on ``tracer`` when given, else the enabled global tracer,
    else a private one — so both ``--trace-out`` runs and standalone calls
    (fig_autotune) work without handle threading."""
    cfg = cfg or AggConfig(strategy="fpisa", backend="jnp")
    spec = get_strategy(cfg.strategy)
    if spec.flat_phases is None:
        raise ValueError(
            f"strategy {cfg.strategy!r} has no split-phase pipeline hooks; "
            f"the phase profiler can only replay split-phase strategies "
            f"(e.g. fpisa)")
    backend = resolve_backend(cfg.backend)
    sizes = tuple(sizes) if sizes is not None else probe_sizes(block=cfg.block)
    for n in sizes:
        if n % cfg.block:
            raise ValueError(
                f"probe sizes must be block multiples (block={cfg.block}), "
                f"got {n}")

    tr = tracer
    if tr is None:
        tr = _trace.get() if _trace.enabled() else _trace.Tracer()

    mesh = compat.make_mesh((jax.device_count(),), tuple(axes))

    def staged(which: int):
        # the phase factory resolves axis sizes, so it must be invoked
        # INSIDE the shard_map context (trace time — free at run time).
        # P() prefix-specs: every input/output leaf fully replicated, which
        # is how the fig11/quickstart harnesses drive the bucketer too
        def fn(arg):
            phases = spec.flat_phases(tuple(axes), cfg, backend)
            return phases[which](arg)

        return jax.jit(compat.shard_map(
            fn, mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False))

    enc_fn, col_fn, fin_fn = staged(0), staged(1), staged(2)

    rng = np.random.default_rng(seed)
    start = len(tr.spans)
    for n in sizes:
        x = jnp.asarray(rng.standard_normal(n).astype(np.float32) * 0.01)
        for _ in range(warmup):
            jax.block_until_ready(fin_fn(col_fn(enc_fn(x))))
        for _ in range(iters):
            with tr.span("autotune.probe", phase="encode", elems=n,
                         strategy=cfg.strategy, backend=backend) as sp:
                state = sp.sync(enc_fn(x))
            with tr.span("autotune.probe", phase="collective", elems=n,
                         strategy=cfg.strategy, backend=backend) as sp:
                collected = sp.sync(col_fn(state))
            with tr.span("autotune.probe", phase="finish", elems=n,
                         strategy=cfg.strategy, backend=backend) as sp:
                sp.sync(fin_fn(collected))
    return tr.spans[start:]
