"""Cost-model autotuning of the aggregation schedule (DESIGN.md §13).

Pipeline: record phase spans (``repro.trace`` / ``profile.profile_phases``)
-> fit the per-phase affine cost model (``costmodel.fit``) -> sweep
candidate bucket plans (``search.choose_bucket_bytes``) -> surface as
``--bucket-bytes auto`` (resolved in ``AggConfig.from_args`` via
``search.auto_bucket_bytes``). Proof benchmark: ``benchmarks/fig_autotune``.
"""
from repro.autotune.costmodel import (  # noqa: F401
    PHASES, CostModel, PhaseCost, fit, fit_from_jsonl,
)
from repro.autotune.profile import probe_sizes, profile_phases  # noqa: F401
from repro.autotune.search import (  # noqa: F401
    DEFAULT_AUTO_BUCKET_BYTES, TRACE_ENV, auto_bucket_bytes,
    candidate_bucket_bytes, choose_bucket_bytes, plan_sizes,
    predict_tree_time, reference_leaves,
)
