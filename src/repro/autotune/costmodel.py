"""Replay-based cost model over recorded phase spans (DESIGN.md §13).

The bucketer's double-buffered pipeline issues, per bucket,
``encode -> collective -> finish`` with the finish of bucket *i-1* and the
encode of bucket *i+1* overlapping the collective of bucket *i*. Each phase's
cost is modeled as affine in the bucket's element count::

    t_phase(n) = a_phase + b_phase * n          (seconds)

fitted by least squares over the ``synced`` spans of a recorded trace (the
spans the tracer actually blocked on — trace-time artifacts from inside a
jit are marked ``synced=False`` and excluded). The fixed cost ``a`` is the
per-dispatch overhead the paper's streaming design amortizes; ``b`` is the
per-element transform/wire cost.

A whole bucket plan is scored with the pipeline recurrence
:meth:`CostModel.pipeline_time`: the collective of bucket *i* hides
``encode(i+1) + finish(i-1)`` (or vice versa — whichever is longer bounds
the stage), which is exactly why an interior bucket size can win: one giant
bucket has no overlap to hide its encode/finish, many tiny buckets pay the
fixed cost ``a`` once per bucket. When replay lies: see DESIGN.md §13.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

PHASES = ("encode", "collective", "finish")


@dataclasses.dataclass(frozen=True)
class PhaseCost:
    a: float  # fixed per-dispatch seconds
    b: float  # per-element seconds

    def __call__(self, elems: int) -> float:
        return self.a + self.b * elems


@dataclasses.dataclass(frozen=True)
class CostModel:
    phases: Mapping[str, PhaseCost]
    samples: Mapping[str, int] = dataclasses.field(default_factory=dict)

    def phase_time(self, phase: str, elems: int) -> float:
        return self.phases[phase](elems)

    def pipeline_time(self, sizes: Sequence[int]) -> float:
        """Predicted wall time of one double-buffered pass over buckets of
        ``sizes`` elements (dispatch order). Stage *i* is bounded by the
        longer of its collective and the overlapped transform work
        ``encode(i+1) + finish(i-1)``; the first encode and last finish
        cannot be hidden."""
        if not sizes:
            return 0.0
        enc = [self.phase_time("encode", n) for n in sizes]
        col = [self.phase_time("collective", n) for n in sizes]
        fin = [self.phase_time("finish", n) for n in sizes]
        k = len(sizes)
        total = enc[0]
        for i in range(k):
            hidden = (enc[i + 1] if i + 1 < k else 0.0) \
                + (fin[i - 1] if i > 0 else 0.0)
            total += max(col[i], hidden)
        total += fin[k - 1]
        return total

    def to_dict(self) -> dict:
        return {
            "phases": {p: {"a": c.a, "b": c.b}
                       for p, c in self.phases.items()},
            "samples": dict(self.samples),
        }


def _phase_samples(spans: Iterable[dict]) -> dict[str, list[tuple[int, float]]]:
    by_phase: dict[str, list[tuple[int, float]]] = {p: [] for p in PHASES}
    for sp in spans:
        tags = sp.get("tags", {})
        phase = tags.get("phase")
        elems = tags.get("elems")
        if phase in by_phase and elems is not None and sp.get("synced"):
            by_phase[phase].append((int(elems), float(sp["dur"])))
    return by_phase


def fit(spans: Iterable[dict]) -> CostModel:
    """Least-squares affine fit per phase from recorded span dicts.

    Requires, per phase, synced samples at >= 2 distinct bucket sizes (a
    single size cannot separate fixed from per-element cost); fails loudly
    otherwise — a cost model silently fitted from nothing would 'tune' the
    bucket plan from noise."""
    by_phase = _phase_samples(spans)
    phases: dict[str, PhaseCost] = {}
    samples: dict[str, int] = {}
    for phase, pts in by_phase.items():
        sizes = {n for n, _ in pts}
        if len(sizes) < 2:
            raise ValueError(
                f"cost model needs synced '{phase}' spans at >= 2 distinct "
                f"bucket sizes, got {len(sizes)} "
                f"({len(pts)} samples); record a trace with "
                f"repro.autotune.profile.profile_phases or --trace-out on a "
                f"bucketed run")
        xs = np.array([n for n, _ in pts], np.float64)
        ys = np.array([t for _, t in pts], np.float64)
        b, a = np.polyfit(xs, ys, 1)
        # noise can drive an intercept/slope slightly negative; costs are not
        phases[phase] = PhaseCost(a=max(float(a), 0.0), b=max(float(b), 0.0))
        samples[phase] = len(pts)
    return CostModel(phases=phases, samples=samples)


def fit_from_jsonl(path) -> CostModel:
    """Fit from a trace file written by the tracer's JSONL export (schema
    checked by ``repro.trace.read_jsonl``)."""
    from repro.trace import read_jsonl

    _, spans = read_jsonl(path)
    return fit(spans)
