"""Logical-axis -> mesh-axis sharding rules.

Parameters get PartitionSpecs by leaf *path + config* pattern matching
(param names in the functional init code are unique; tests assert coverage
for every arch).

Mesh axes: ('pod',) 'data', 'model'.

Attention TP mode is chosen per architecture from divisibility against the
'model' axis size m:
  head  : H % m == 0 and K % m == 0     -> q,k,v sharded on their head axes
  qhead : H % m == 0 only               -> q sharded on heads, k/v weights
          replicated (Megatron-style KV duplication; GQA repeat aligns them)
  hdim  : head_dim % m == 0             -> q,k,v sharded on head_dim
          (contraction-sharded scores; costs an all-reduce — visible in the
          roofline, a hillclimb target for arctic/llava)
  none  : replicated attention weights.

MoE: experts over 'model', expert ff over 'data' (so the 1T kimi bank fits);
FSDP ('data' on embed axes) turns on when cfg.dp_boundary == 'pod'.
Optimizer m/v shard their first free divisible axis over 'data' (ZeRO-1).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def attn_mode(cfg, model_size: int) -> str:
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if h == 0:
        return "none"
    if h % model_size == 0 and k % model_size == 0:
        return "head"
    if h % model_size == 0:
        return "qhead"
    if hd % model_size == 0:
        return "hdim"
    return "none"


def _div(n: int, size: int, axis="model"):
    return axis if n % size == 0 else None


def _param_spec(path: str, ndim: int, cfg, m: int, dsz: int) -> P:
    fsdp = cfg.dp_boundary == "pod"
    d = "data" if fsdp else None
    am = attn_mode(cfg, m)

    def match(*pats):
        return any(re.search(p, path) for p in pats)

    if match(r"embed/tok"):
        return P(_div(cfg.vocab_size, m), d)
    if match(r"head/w"):
        return P(d, _div(cfg.vocab_size, m))
    if match(r"vlm_proj", r"frame_proj"):
        return P(d, _div(cfg.d_model, m))
    if match(r"attn/wq$", r"xattn/wq$"):
        if am == "head" or am == "qhead":
            return P(d, "model", None)
        if am == "hdim":
            return P(d, None, "model")
        return P(d, None, None)
    if match(r"attn/w[kv]$", r"xattn/w[kv]$"):
        if am == "head":
            return P(d, "model", None)
        if am == "hdim":
            return P(d, None, "model")
        return P(d, None, None)  # qhead: replicated KV (Megatron duplication)
    if match(r"attn/wo$", r"xattn/wo$"):
        if am in ("head", "qhead"):
            return P("model", None, d)
        if am == "hdim":
            return P(None, "model", d)
        return P(None, None, d)
    if match(r"attn/bq$", r"xattn/bq$"):
        return P("model" if am in ("head", "qhead") else None, None)
    if match(r"attn/b[kv]$", r"xattn/b[kv]$"):
        return P("model" if am == "head" else None, None)
    if match(r"moe/router"):
        return P(d, None)
    if match(r"moe/wi$", r"moe/wg$"):
        return P(_div(cfg.num_experts, m), None, _div(cfg.d_ff, dsz, "data"))
    if match(r"moe/wo$"):
        return P(_div(cfg.num_experts, m), _div(cfg.d_ff, dsz, "data"), None)
    if match(r"dense_mlp/wi$", r"dense_mlp/wg$"):
        return P(d, _div(cfg.moe_dense_ff, m))
    if match(r"dense_mlp/wo$"):
        return P(_div(cfg.moe_dense_ff, m), d)
    if match(r"mlp/wi$", r"mlp/wg$"):
        return P(d, _div(cfg.d_ff, m))
    if match(r"mlp/wo$"):
        return P(_div(cfg.d_ff, m), d)
    if match(r"mamba/in_proj"):
        proj = 2 * cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads
        return P(d, _div(proj, m))
    if match(r"mamba/out_proj"):
        return P(_div(cfg.ssm_d_inner, m), d)
    if match(r"mamba/conv_w"):
        conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        return P(None, _div(conv_ch, m))
    if match(r"mamba/conv_b"):
        conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        return P(_div(conv_ch, m))
    if match(r"mamba/norm_w"):
        return P(_div(cfg.ssm_d_inner, m))
    # small vectors: norms, a_log, dt_bias, d_skip
    return P(*([None] * ndim))


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in kp
        )
        out.append((path, leaf))
    return out, treedef


_STACKED_RE = re.compile(r"(^|/)(layers|tail_layers|enc_layers|dec_layers)(/|$)")


def _drop_missing_axes(spec: P, mesh) -> P:
    """Null out mesh axes a rule names but this mesh doesn't have (e.g. a
    pure-DP (pod, data) mesh has no 'model' axis — those dims replicate)."""
    names = set(mesh.axis_names)

    def keep(p):
        if isinstance(p, tuple):
            kept = tuple(a for a in p if a in names)
            return kept if kept else None
        return p if (p is None or p in names) else None

    return P(*(keep(p) for p in spec))


def param_pspecs(params, cfg, mesh: Mesh):
    """PartitionSpec pytree mirroring `params` (shape-dtype structs are fine)."""
    m = mesh.shape.get("model", 1)
    dsz = mesh.shape.get("data", 1)
    flat, treedef = _tree_paths(params)
    specs = []
    for path, leaf in flat:
        stacked = bool(_STACKED_RE.search(path))
        extra = 0
        if stacked:
            extra = 2 if (cfg.family == "hybrid" and path.startswith("layers/")) else 1
        spec = _param_spec(path, leaf.ndim - extra, cfg, m, dsz)
        spec = _drop_missing_axes(P(*([None] * extra + list(spec))), mesh)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_pspecs(param_specs, params, mesh: Mesh):
    """AdamW m/v sharding: add 'data' on the first unsharded axis divisible by
    the data-axis size (ZeRO-1 memory layout)."""
    data = mesh.shape.get("data", 1)

    def one(spec: P, leaf):
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        flatparts = [
            q for p in parts for q in ((p,) if not isinstance(p, tuple) else p)
        ]
        if "data" in flatparts:
            return P(*parts)
        for i, p in enumerate(parts):
            if p is None and leaf.shape[i] % data == 0 and leaf.shape[i] >= data:
                parts[i] = "data"
                return P(*parts)
        return P(*parts)

    return jax.tree.map(
        one, param_specs, params, is_leaf=lambda x: isinstance(x, P)
    )


def named(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree. Axes a rule names but this
    mesh doesn't have are dropped here, at the point where every spec producer
    (param/opt/cache/input) meets a concrete mesh."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _drop_missing_axes(s, mesh)), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_axes(mesh: Mesh, batch_size: int):
    use = []
    rem = batch_size
    for a in ("pod", "data"):
        if a in mesh.axis_names and rem % mesh.shape[a] == 0:
            use.append(a)
            rem //= mesh.shape[a]
    return tuple(use)


def batch_pspec(mesh: Mesh, batch_size: int):
    use = batch_axes(mesh, batch_size)
    return P(use if use else None)


def input_pspecs(batch, mesh: Mesh, batch_size: int):
    """Shard every batch input on its leading (batch) axis."""
    spec = batch_pspec(mesh, batch_size)

    def one(leaf):
        return P(*(list(spec) + [None] * (leaf.ndim - 1)))

    return jax.tree.map(one, batch)


def cache_pspecs(cache, mesh: Mesh, batch_size: int, cfg):
    """Serving-cache shardings. KV heads shard over 'model' when divisible;
    otherwise the cache *sequence* axis shards over 'model' (context-parallel
    decode). Batch shards over replica axes; for batch=1 long-context the seq
    axis also takes 'data'."""
    m = mesh.shape.get("model", 1)
    b_axes = batch_axes(mesh, batch_size) or None
    kv_div = cfg.num_kv_heads and cfg.num_kv_heads % m == 0
    seq_parts = []
    if batch_size == 1 and "data" in mesh.axis_names:
        seq_parts.append("data")
    if not kv_div and "model" in mesh.axis_names and cfg.num_kv_heads:
        seq_parts.append("model")
    seq_spec = tuple(seq_parts) if seq_parts else None

    def one(path, leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return P()
        if re.search(r"(^|/)(kv|self_kv)/[kv]$|cross_kv/[01]$", path):
            # (L, B, S, K, hd)
            return P(None, b_axes, seq_spec, "model" if kv_div else None, None)
        if re.search(r"(^|/)ssm$", path):
            # (L, B, H, P, N)
            return P(None, b_axes, _div(cfg.ssm_heads, m), None, None)
        if re.search(r"(^|/)conv$", path):
            conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            return P(None, b_axes, None, _div(conv_ch, m))
        return P()

    flat, treedef = _tree_paths(cache)
    return jax.tree_util.tree_unflatten(treedef, [one(p, l) for p, l in flat])
