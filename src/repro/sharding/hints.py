"""In-model sharding constraints that degrade to no-ops without a mesh.

Model code calls ``constrain(x, *axes)`` with logical placements; if a global
mesh context is active (jax.sharding.set_mesh — done by the launchers), a
with_sharding_constraint is emitted using only the axes that exist on that
mesh; otherwise the call is a no-op so single-device tests and examples are
unaffected.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _mesh_axis_names():
    """Names of AUTO axes on the active abstract mesh (manual shard_map axes
    must not appear in sharding constraints)."""
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return ()
    if m is None or not getattr(m, "axis_names", None):
        return ()
    try:
        types = dict(zip(m.axis_names, m.axis_types))
        return tuple(
            a for a, t in types.items() if t == jax.sharding.AxisType.Auto
        )
    except Exception:  # noqa: BLE001
        return tuple(m.axis_names)


def batch_axes():
    names = _mesh_axis_names()
    return tuple(a for a in ("pod", "data") if a in names)


def constrain(x, *placements):
    """placements: per-dim placement; each is None, an axis name, 'batch'
    (expands to the replica axes present), or a tuple of axis names. Axes not
    present on the active mesh are dropped; without a mesh this is identity.
    """
    names = _mesh_axis_names()
    if not names:
        return x
    parts = []
    for pl in placements:
        if pl is None:
            parts.append(None)
        elif pl == "batch":
            ba = batch_axes()
            parts.append(ba if ba else None)
        elif isinstance(pl, tuple):
            keep = tuple(a for a in pl if a in names)
            parts.append(keep if keep else None)
        else:
            parts.append(pl if pl in names else None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*parts))
    except Exception:  # pragma: no cover — constraint invalid for this mesh
        return x
