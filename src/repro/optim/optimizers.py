"""Optimizers from scratch (no optax in this environment).

AdamW keeps m/v in float32 (params may be bf16; the update math runs in
fp32 and casts back — no separate master copy, which halves optimizer memory
at a well-understood precision cost; see DESIGN.md). State layouts are plain
pytrees mirroring params so sharding rules (ZeRO-1 'data' sharding) apply
leaf-wise.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | sgdm
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9
    grad_clip: float = 1.0
    warmup_steps: int = 100


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any  # None for sgdm


def init(params, cfg: OptConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    m = jax.tree.map(zeros, params)
    v = jax.tree.map(zeros, params) if cfg.name == "adamw" else None
    return OptState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def _schedule(cfg: OptConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def _clip(grads, max_norm):
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def update(params, grads, state: OptState, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = _schedule(cfg, step)
    grads, gnorm = _clip(grads, cfg.grad_clip)

    if cfg.name == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, mm, vv):
            u = (mm / c1) / (jnp.sqrt(vv / c2) + cfg.eps)
            pf = p.astype(jnp.float32)
            pf = pf - lr * (u + cfg.weight_decay * pf)
            return pf.astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        new_state = OptState(step=step, m=m, v=v)
    elif cfg.name == "sgdm":
        m = jax.tree.map(lambda mm, g: cfg.momentum * mm + g, state.m, grads)

        def upd(p, mm):
            pf = p.astype(jnp.float32) - lr * mm
            return pf.astype(p.dtype)

        new_params = jax.tree.map(upd, params, m)
        new_state = OptState(step=step, m=m, v=None)
    else:
        raise ValueError(cfg.name)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
