"""Failure-injecting elastic training controller: survive worker death across
the switch dataplane and the training runtime.

The paper's in-network aggregation keeps per-job state (slot pool, worker
bitmaps) INSIDE the switch, so a worker death is not just a scheduler event:
unfilled completion bitmaps park switch slots forever unless the control
plane reclaims them. This controller ties the whole recovery path together in
one emulated cluster:

* **Logical workers.** The job has W fixed logical workers (= switch ports =
  data shards), decoupled from the physical mesh. Each mesh shard hosts
  W / mesh_size of them and the gradients aggregate through the stacked
  integer-domain collectives (core/allreduce.py), whose bits are identical on
  ANY mesh dividing W. That invariance is what makes elastic recovery exact.

* **Heartbeats.** Hosts heartbeat after every step into a ``HealthMonitor``
  driven by the controller's simulated clock (1 tick / step). A fault plan
  (``parse_fault_plan``) silences a host from step k on; the monitor's
  timeout declares it dead a few steps later — detection latency is real and
  measured (``steps_to_detect`` in the recovery report).

* **Switch reclamation.** The controller mirrors the job's streaming window
  on a persistent emulated dataplane (one port per mesh host, monotone chunk
  ids via ``chunk_base``). On a declared death the in-flight window is
  drained with the failure injected: ``run_aggregation(fail_worker=...)``
  reclaims the dead port's parked slots (``reclaimed`` stat) and the
  survivors' shadow-copy retransmissions complete every chunk — no slot is
  left parked. The dataplane is then rebuilt for the survivor port set.

* **Data failover.** Shard ownership is re-derived from
  ``HealthMonitor.reassignments`` every step: a dead host's shard loader is
  rebuilt on its replacement via ``data/pipeline.reassign_shard`` (the
  deterministic stream makes the global batch content identical), and a
  revival retracts the reassignment again.

* **Elastic resume.** Checkpoints are atomic params+opt bundles labeled with
  the NEXT step to run. On recovery the controller discards checkpoints
  tainted by the dead host (committed after its last heartbeat), restores the
  newest clean bundle onto the survivor mesh via ``elastic.resume_on_mesh``,
  rebuilds the jitted step (which re-plans the bucketed collective for the
  new mesh), and replays. Replayed losses are asserted bit-equal to the
  originally recorded ones — the bit-identical-resume invariant, enforced at
  runtime, not just in tests (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile
import time

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import switchsim
from repro import trace as _trace
from repro.core.agg import AggConfig, Aggregator
from repro.data.pipeline import ShardedLoader, SyntheticCorpus, reassign_shard
from repro.models.registry import build, param_count
from repro.optim import optimizers
from repro.runtime import checkpoint as ckpt
from repro.runtime import elastic
from repro.runtime.health import HealthMonitor
from repro.sharding import rules
from repro.train.step import make_train_step


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str  # "kill" | "revive" | "slow"
    host: int
    factor: float = 1.0  # "slow" only: reported step-time multiplier


def parse_fault_plan(spec: str | None) -> tuple[FaultEvent, ...]:
    """Parse ``kill:<host>@<step>[,revive:<host>@<step>,slow:<host>@<step>x<f>]``.

    Examples: ``kill:2@5``; ``kill:2@5,revive:2@20``; ``slow:3@4x6``.
    ``kill`` silences the host's heartbeats from that step on; ``revive``
    resumes them; ``slow`` multiplies the host's reported step times (a
    degrading host for the straggler detector) until the next event."""
    if not spec:
        return ()
    events = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            kind, rest = part.split(":", 1)
            host_s, at = rest.split("@", 1)
            factor = 1.0
            if "x" in at:
                at, f = at.split("x", 1)
                factor = float(f)
            ev = FaultEvent(step=int(at), kind=kind, host=int(host_s),
                            factor=factor)
        except ValueError as e:
            raise ValueError(f"bad fault-plan entry {part!r} "
                             f"(want kind:host@step[xfactor])") from e
        if ev.kind not in ("kill", "revive", "slow"):
            raise ValueError(f"unknown fault kind {ev.kind!r} in {part!r}")
        events.append(ev)
    return tuple(sorted(events, key=lambda e: e.step))


@dataclasses.dataclass
class RecoveryReport:
    detected_at_step: int      # step after which the death was declared
    dead: list[int]
    last_good_step: int        # newest step known completed by every dead host
    resumed_from: int          # next-step label of the restored checkpoint
    steps_to_detect: int       # kill -> declaration latency (heartbeat timeout)
    steps_replayed: int        # resumed_from .. detected_at_step replay length
    mesh_hosts: list[int]      # survivor hosts backing the new mesh
    reclaimed: int             # switch slots freed by dead-port reclamation
    switch_stats: dict         # dataplane counters at teardown (incl. reclaimed)


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


class ElasticController:
    """Drives the training loop with heartbeats, fault injection, switch-slot
    reclamation and bit-identical elastic resume (module doc).

    ``run()`` returns a summary dict:
      ``history``     — {step: loss} for every step 0..steps-1 (final values)
      ``recoveries``  — [RecoveryReport as dict, ...]
      ``stragglers``  — {step: [hosts flagged]}
      ``switch``      — final dataplane counters (incl. ``reclaimed`` total)
    """

    def __init__(self, cfg, *, steps: int, global_batch: int, seq_len: int,
                 agg: AggConfig, num_hosts: int | None = None,
                 ckpt_dir: str | None = None, ckpt_every: int = 5,
                 fault_plan: tuple[FaultEvent, ...] | str = (),
                 seed: int = 0, heartbeat_timeout: float = 2.5,
                 switch_slots: int = 4, switch_elems: int = 64,
                 fingerprint_elems: int = 512, opt_overrides: dict | None = None,
                 log_every: int = 10, strict_replay: bool = True):
        self.cfg = cfg
        self.steps = steps
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.agg = agg
        # validate the aggregation config through the facade ONCE, up front:
        # the controller always re-meshes onto data-only meshes and runs the
        # stacked (logical-worker) collectives, so a strategy that cannot
        # stack — or any bad strategy/backend/chunk combination — fails here,
        # not deep inside the first re-trace after a failure
        self.aggregator = Aggregator(agg, ("data",), stacked=True)
        self.devices = jax.devices()
        self.num_hosts = num_hosts or len(self.devices)
        if self.num_hosts > len(self.devices):
            raise ValueError(f"num_hosts={self.num_hosts} exceeds "
                             f"{len(self.devices)} devices")
        if global_batch % self.num_hosts:
            raise ValueError(f"global_batch={global_batch} must divide over "
                             f"num_hosts={self.num_hosts} logical workers")
        self.ckpt_dir = ckpt_dir or tempfile.mkdtemp(prefix="fpisa_ctl_")
        # a controller run owns its checkpoint namespace from step 0: bundles
        # left by a previous job would otherwise win latest_step on recovery
        # (restoring another run's params) or evict this run's fresh bundles
        # through the keep=N retention
        self._reset_ckpt_dir()
        self.ckpt_every = max(1, ckpt_every)
        self.fault_plan = (parse_fault_plan(fault_plan)
                           if isinstance(fault_plan, str) else tuple(fault_plan))
        for ev in self.fault_plan:
            # an out-of-range kill would silently never fire and a matching
            # revive would KeyError the heartbeat loop mid-run — refuse early
            if not 0 <= ev.host < self.num_hosts:
                raise ValueError(
                    f"fault plan names host {ev.host} but the job has "
                    f"{self.num_hosts} hosts (0..{self.num_hosts - 1})")
        self.seed = seed
        self.switch_slots = switch_slots
        self.switch_elems = switch_elems
        self.fingerprint_elems = fingerprint_elems
        self.log_every = log_every
        self.strict_replay = strict_replay

        self.model = build(cfg)
        opt_kw = {"name": cfg.optimizer, "lr": cfg.learning_rate}
        opt_kw.update(opt_overrides or {})
        self.opt_cfg = optimizers.OptConfig(**opt_kw)

        # W logical workers == data shards; host h primarily owns shard h
        w = self.num_hosts
        self.corpus = SyntheticCorpus(cfg.vocab_size, seed)
        self._primary = {
            h: ShardedLoader(self.corpus, global_batch, seq_len,
                             shard_id=h, num_shards=w)
            for h in range(w)
        }
        self._shard_loaders = dict(self._primary)  # shard -> current loader
        self._shard_owner = {s: s for s in range(w)}

        # simulated control-plane clock: 1 tick per training step
        self._now = 0.0
        self.health = HealthMonitor(hosts=list(range(w)),
                                    timeout=heartbeat_timeout,
                                    clock=lambda: self._now)
        self._beating = set(range(w))     # hosts currently sending heartbeats
        self._slow = {}                   # host -> step-time multiplier
        self._last_beat_step = {h: -1 for h in range(w)}

        # host-side templates for elastic restore (shape/dtype only)
        params0 = self.model.init(jax.random.PRNGKey(seed))
        self._like_params = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params0)
        opt0 = optimizers.init(params0, self.opt_cfg)
        self._like_opt = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt0)
        self._params0_host = jax.device_get(params0)
        self._opt0_host = jax.device_get(opt0)

        self.mesh_hosts: list[int] = []
        self.switch = None
        self._chunk_base = 0
        self.recoveries: list[RecoveryReport] = []
        self.straggler_log: dict[int, list[int]] = {}
        self._reclaimed_total = 0
        self._remesh(sorted(self._beating), restore=False)

    # -- mesh / switch lifecycle ------------------------------------------

    def _remesh(self, survivors: list[int], restore: bool,
                max_step: int | None = None) -> int:
        """(Re)build mesh + jitted step on ``survivors``; returns the next
        step to run (0 when starting fresh, the restored label otherwise)."""
        w = self.num_hosts
        d = _largest_divisor_leq(w, len(survivors))
        self.mesh_hosts = survivors[:d]
        devs = [self.devices[h] for h in self.mesh_hosts]
        # data-only mesh: fully-manual shard_map, so host-callback strategies
        # (switch_emu) work; sharding rules drop mesh-absent axes (PR 1)
        self.mesh = elastic.make_mesh_for(devices=devs, data_only=True)

        next_step = 0
        restored = False
        if restore:
            if max_step is not None:
                self._drop_tainted_checkpoints(max_step)
            res = elastic.resume_on_mesh(self.ckpt_dir, self._like_params,
                                         self._like_opt, self.cfg, self.mesh)
            if res is not None:
                self.params, self.opt_state, extra = res
                next_step = extra["step"]
                restored = True
        if not restored:
            self._place_initial()
        # rebuilding the step re-traces stacked_allreduce_tree on the new
        # mesh: the bucket plan and wire shift re-derive for the new k
        self.step_fn = jax.jit(make_train_step(
            self.model, self.mesh, self.agg, self.opt_cfg, self.global_batch,
            logical_workers=w))
        self._bspec = rules.batch_pspec(self.mesh, self.global_batch)

        # fresh switch for the new port set (one port per mesh host)
        self.switch = switchsim.NumpyDataplane(switchsim.DataplaneConfig(
            num_workers=len(self.mesh_hosts), num_slots=self.switch_slots,
            elems_per_packet=self.switch_elems))
        return next_step

    def _place_initial(self):
        pspecs = rules.param_pspecs(self._params0_host, self.cfg, self.mesh)
        self.params = jax.device_put(self._params0_host,
                                     rules.named(self.mesh, pspecs))
        ospecs = rules.opt_pspecs(pspecs, self._params0_host, self.mesh)
        o = self._opt0_host
        self.opt_state = optimizers.OptState(
            step=jax.device_put(o.step, NamedSharding(self.mesh, P())),
            m=jax.device_put(o.m, rules.named(self.mesh, ospecs)),
            v=None if o.v is None else jax.device_put(
                o.v, rules.named(self.mesh, ospecs)),
        )

    def _reset_ckpt_dir(self):
        if not os.path.isdir(self.ckpt_dir):
            return
        wiped = 0
        for name in os.listdir(self.ckpt_dir):
            if name.startswith("step_"):
                shutil.rmtree(os.path.join(self.ckpt_dir, name),
                              ignore_errors=True)
                wiped += 1
            elif name in ("latest", "latest.tmp"):
                os.remove(os.path.join(self.ckpt_dir, name))
        if wiped:
            print(f"[controller] reset ckpt dir {self.ckpt_dir}: removed "
                  f"{wiped} stale checkpoint(s) from a previous run")

    def _drop_tainted_checkpoints(self, max_step: int):
        """Remove bundles committed after the dead hosts' last heartbeat —
        they were written from state the dead host never contributed to."""
        for s in ckpt.committed_steps(self.ckpt_dir):
            if s > max_step:
                shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"),
                              ignore_errors=True)
        latest = os.path.join(self.ckpt_dir, "latest")
        if os.path.exists(latest):
            os.remove(latest)  # force the directory-scan fallback

    # -- data / switch per-step machinery ---------------------------------

    def _sync_loaders(self):
        """Derive shard -> loader from the monitor's reassignment table (the
        single source of truth, so revivals retract automatically)."""
        for s in range(self.num_hosts):
            owner = self.health.reassignments.get(s, s)
            if owner != self._shard_owner[s]:
                self._shard_loaders[s] = (
                    self._primary[s] if owner == s
                    else reassign_shard(self._primary[owner], new_shard_id=s))
                self._shard_owner[s] = owner

    def _global_tokens(self, step: int) -> np.ndarray:
        parts = [self._shard_loaders[s].batch_at(step)["tokens"]
                 for s in range(self.num_hosts)]
        return np.concatenate(parts, axis=0)

    def _fingerprints(self, step: int) -> np.ndarray:
        """Per-port shadow payloads mirroring the step's streaming window."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 0x5717C4, step]))
        return (rng.standard_normal(
            (len(self.mesh_hosts), self.fingerprint_elems)) * 0.1
        ).astype(np.float32)

    def _switch_step(self, step: int, fail_port: int | None = None) -> dict:
        vecs = self._fingerprints(step)
        switchsim.run_aggregation(
            self.switch, vecs, chunk_base=self._chunk_base,
            fail_worker=fail_port, fail_round=1 if fail_port is not None else None)
        self._chunk_base += -(-self.fingerprint_elems // self.switch_elems)
        return dict(self.switch.stats)

    # -- main loop ---------------------------------------------------------

    def run(self) -> dict:
        w = self.num_hosts
        print(f"[controller] {self.cfg.name}: "
              f"{param_count(self._params0_host)/1e6:.1f}M params, "
              f"W={w} logical workers, mesh={dict(self.mesh.shape)}, "
              f"agg={self.agg.strategy}, faults={list(self.fault_plan)}")
        history: dict[int, float] = {}
        timeline: list[dict] = []  # chronological, replays included
        # initial clean bundle so a pre-first-checkpoint death can restore
        ckpt.save_bundle(self.ckpt_dir, 0,
                         {"params": self.params, "opt": self.opt_state})
        step = 0
        wall0 = time.perf_counter()
        while step < self.steps:
            for ev in self.fault_plan:
                if ev.step == step:
                    if ev.kind == "kill":
                        self._beating.discard(ev.host)
                    elif ev.kind == "revive":
                        self._beating.add(ev.host)
                        self._slow.pop(ev.host, None)
                    elif ev.kind == "slow":
                        self._slow[ev.host] = ev.factor

            t0 = time.perf_counter()
            with _trace.span("controller.step", phase="step", step=step,
                             mesh=len(self.mesh_hosts)) as sp:
                tokens = jax.device_put(
                    self._global_tokens(step),
                    NamedSharding(self.mesh, P(*self._bspec, None)))
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, {"tokens": tokens})
                loss = float(metrics["loss"])  # blocks: device step lands here
                sp.sync(metrics)
            dt = time.perf_counter() - t0

            if self.strict_replay and step in history:
                assert history[step] == loss, (
                    f"replayed step {step} diverged: {history[step]} != {loss} "
                    f"(bit-identical elastic resume violated)")
            history[step] = loss
            timeline.append({"step": step, "loss": loss, "dt": dt,
                             "mesh": len(self.mesh_hosts)})
            self._switch_step(step)

            # heartbeats + failure detection on the simulated clock
            self._now += 1.0
            for h in sorted(self._beating):
                self.health.heartbeat(h, dt * self._slow.get(h, 1.0))
                self._last_beat_step[h] = step
            res = self.health.check()
            if res["stragglers"]:
                self.straggler_log[step] = res["stragglers"]
            self._sync_loaders()

            if step % self.log_every == 0 or step == self.steps - 1:
                tok_s = self.global_batch * self.seq_len / max(dt, 1e-9)
                print(f"[controller] step {step:5d} loss {loss:.4f} "
                      f"{tok_s:,.0f} tok/s mesh={len(self.mesh_hosts)}")

            if res["dead"]:
                step = self._recover(res["dead"], step)
                continue

            # revived host available again and capacity to grow? re-mesh up.
            alive = sorted(h for h, s in self.health.hosts.items() if s.alive)
            if _largest_divisor_leq(w, len(alive)) > len(self.mesh_hosts):
                step = self._grow(alive, step)
                continue

            step += 1
            if step % self.ckpt_every == 0 or step == self.steps:
                ckpt.save_bundle(self.ckpt_dir, step,
                                 {"params": self.params, "opt": self.opt_state},
                                 {"loss": loss})
        print(f"[controller] done: {self.steps} steps in "
              f"{time.perf_counter() - wall0:.1f}s, "
              f"{len(self.recoveries)} recoveries, "
              f"{self._reclaimed_total} switch slots reclaimed")
        return {
            "history": [history[s] for s in range(self.steps)],
            "timeline": timeline,
            "recoveries": [dataclasses.asdict(r) for r in self.recoveries],
            "stragglers": self.straggler_log,
            "switch": dict(self.switch.stats),
            "mesh_hosts": list(self.mesh_hosts),
        }

    # -- recovery ----------------------------------------------------------

    def _recover(self, dead: list[int], step: int) -> int:
        """Full recovery path after declared deaths; returns the next step."""
        with _trace.span("controller.recover", phase="recover", step=step,
                         dead=list(dead)):
            # 1. switch-side: drain the in-flight window with the failure
            #    live — the dead ports' slots are reclaimed and survivors
            #    resubmit from shadow copies; completing proves no slot
            #    stays parked.
            with _trace.span("recover.drain_switch", phase="recover"):
                stats = dict(self.switch.stats)
                for h in dead:
                    if h in self.mesh_hosts:
                        stats = self._switch_step(
                            step, fail_port=self.mesh_hosts.index(h))
            reclaimed = stats["reclaimed"]
            self._reclaimed_total += reclaimed

            # 2. the dead hosts' contributions stop at their last heartbeat:
            #    anything newer (including checkpoints) is tainted.
            last_good = min(self._last_beat_step[h] for h in dead)
            survivors = sorted(
                h for h, s in self.health.hosts.items() if s.alive)
            if not survivors:
                raise RuntimeError("all hosts dead; nothing to recover onto")

            # 3. re-mesh the survivors + elastic restore of the newest clean
            #    bundle
            with _trace.span("recover.restore", phase="recover") as sp:
                resumed_from = self._remesh(survivors, restore=True,
                                            max_step=last_good + 1)
                sp.sync(self.params)
        report = RecoveryReport(
            detected_at_step=step, dead=list(dead),
            last_good_step=last_good, resumed_from=resumed_from,
            steps_to_detect=step - last_good,
            steps_replayed=max(0, step + 1 - resumed_from),
            mesh_hosts=list(self.mesh_hosts), reclaimed=reclaimed,
            switch_stats=stats)
        self.recoveries.append(report)
        print(f"[controller] RECOVERY dead={dead} detected@{step} "
              f"last_good={last_good} resume@{resumed_from} "
              f"mesh={self.mesh_hosts} reclaimed={reclaimed}")
        return resumed_from

    def _grow(self, alive: list[int], step: int) -> int:
        """Scale back up onto revived hosts: checkpoint current state, then
        re-mesh + restore (no replay needed — the state is clean)."""
        with _trace.span("controller.grow", phase="recover", step=step) as sp:
            ckpt.save_bundle(self.ckpt_dir, step + 1,
                             {"params": self.params, "opt": self.opt_state})
            resumed_from = self._remesh(alive, restore=True)
            sp.sync(self.params)
        print(f"[controller] GROW mesh={self.mesh_hosts} resume@{resumed_from}")
        return resumed_from


def run_controller(cfg, *, steps, global_batch, seq_len,
                   agg: AggConfig | None = None, agg_strategy="fpisa",
                   agg_backend="auto", agg_bucket_bytes=0, num_hosts=None,
                   ckpt_dir=None, ckpt_every=5, fault_plan="", seed=0,
                   log_every=10, opt_overrides=None) -> dict:
    """Launcher-facing wrapper (launch/train.py ``--fault-plan`` path).

    Prefer passing one ``agg`` config; the loose ``agg_*`` kwargs are kept
    for backwards compatibility and ignored when ``agg`` is given."""
    if agg is None:
        agg = AggConfig(strategy=agg_strategy, backend=agg_backend,
                        bucket_bytes=agg_bucket_bytes)
    ctl = ElasticController(
        cfg, steps=steps, global_batch=global_batch, seq_len=seq_len, agg=agg,
        num_hosts=num_hosts, ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
        fault_plan=fault_plan, seed=seed, log_every=log_every,
        opt_overrides=opt_overrides)
    return ctl.run()
