"""Fault-tolerant checkpointing: atomic commits, retention, async writes,
mesh-independent restore (elastic resharding is layered on top in elastic.py).

Layout:
  <dir>/step_<n>.tmp/      while writing
  <dir>/step_<n>/          after atomic rename (commit point)
      manifest.json        {leaf path -> {file, shape, dtype}}, step, extra
      <i>.npy              one file per leaf (host-gathered global arrays)
  <dir>/latest             text file holding the newest committed step

Bundle layout (``save_bundle``) — params AND optimizer state (and any other
named trees) commit in ONE atomic rename, so they can never land on
different latest steps (the failure mode of the old split
``<dir>`` / ``<dir>_opt`` scheme: a crash between the two saves left a
params step with no matching opt step, and a restart silently mixed steps):
  <dir>/step_<n>/
      manifest.json        {"step": n, "extra": ..., "trees": ["params","opt"]}
      params/manifest.json + <i>.npy
      opt/manifest.json    + <i>.npy

``latest_step`` only reports steps whose manifest AND every listed tree's
manifest + leaf files exist — partially-written checkpoints (a crash
mid-save, a torn copy) are never visible to a restart.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in kp)
        for kp, _ in flat
    ]
    return paths, [l for _, l in flat], treedef


def _write_manifest(d: str, manifest: dict) -> None:
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())


def _write_tree(d: str, step: int, tree: Any, extra: dict | None = None) -> None:
    """Write one tree's leaves + manifest into ``d`` (no commit semantics)."""
    paths, leaves, _ = _flatten(tree)
    os.makedirs(d, exist_ok=True)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{i}.npy"
        np.save(os.path.join(d, fname), arr)
        manifest["leaves"][p] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    _write_manifest(d, manifest)


def _commit(ckpt_dir: str, step: int, tmp: str, keep: int) -> str:
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # commit point
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(ckpt_dir, "latest.tmp"), os.path.join(ckpt_dir, "latest"))
    _retain(ckpt_dir, keep)
    return final


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None,
         keep: int = 3) -> str:
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    _write_tree(tmp, step, tree, extra)
    return _commit(ckpt_dir, step, tmp, keep)


def save_bundle(ckpt_dir: str, step: int, trees: dict[str, Any],
                extra: dict | None = None, keep: int = 3) -> str:
    """Atomically commit several named trees (e.g. params + opt) as ONE step.

    All trees are staged under ``step_<n>.tmp`` and become visible through a
    single rename — a crash at any point leaves either the complete step or
    nothing, never params without opt (module doc)."""
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    os.makedirs(tmp, exist_ok=True)
    names = sorted(trees)
    for name in names:
        _write_tree(os.path.join(tmp, name), step, trees[name])
    _write_manifest(tmp, {"step": step, "extra": extra or {}, "trees": names})
    return _commit(ckpt_dir, step, tmp, keep)


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(committed_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def committed_steps(ckpt_dir: str):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            path = os.path.join(ckpt_dir, name, "manifest.json")
            if os.path.exists(path):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
    return out


def latest_step(ckpt_dir: str) -> int | None:
    """Newest *valid* checkpoint — prefers the `latest` pointer but falls back
    to a directory scan if the pointer is stale or the target is corrupt."""
    candidates = sorted(committed_steps(ckpt_dir), reverse=True)
    ptr = os.path.join(ckpt_dir, "latest")
    if os.path.exists(ptr):
        try:
            s = int(open(ptr).read().strip())
            if s in candidates and _valid(ckpt_dir, s):
                return s
        except (ValueError, OSError):
            pass
    for s in candidates:
        if _valid(ckpt_dir, s):
            return s
    return None


def _leaves_present(d: str, manifest: dict) -> bool:
    for meta in manifest.get("leaves", {}).values():
        if not os.path.exists(os.path.join(d, meta["file"])):
            return False
    return True


def _valid(ckpt_dir: str, step: int) -> bool:
    """A step is valid only when its manifest AND — for bundles — every tree
    listed in it committed completely (all subtree manifests + leaf files)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    try:
        manifest = json.load(open(os.path.join(d, "manifest.json")))
    except (OSError, json.JSONDecodeError):
        return False
    if not _leaves_present(d, manifest):
        return False
    for name in manifest.get("trees", ()):
        sub = os.path.join(d, name)
        try:
            sub_manifest = json.load(open(os.path.join(sub, "manifest.json")))
        except (OSError, json.JSONDecodeError):
            return False
        if not _leaves_present(sub, sub_manifest):
            return False
    return True


def _restore_dir(d: str, like: Any) -> tuple[Any, dict]:
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    paths, leaves, treedef = _flatten(like)
    out = []
    for p, leaf in zip(paths, leaves):
        meta = manifest["leaves"][p]
        arr = np.load(os.path.join(d, meta["file"]))
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {p}: {arr.shape} vs {want}")
        out.append(arr.astype(getattr(leaf, "dtype", arr.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def restore(ckpt_dir: str, step: int, like: Any) -> tuple[Any, dict]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). Returns (tree, extra)."""
    return _restore_dir(os.path.join(ckpt_dir, f"step_{step}"), like)


def restore_bundle(ckpt_dir: str, step: int,
                   likes: dict[str, Any]) -> tuple[dict[str, Any], dict]:
    """Restore the named trees of a bundle step (``save_bundle`` layout).
    Trees whose ``like`` is None are skipped (returned as None)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    if "trees" not in manifest:
        raise ValueError(
            f"step {step} in {ckpt_dir} is not a bundle checkpoint "
            f"(manifest has no 'trees'); use restore() for single-tree steps")
    out = {}
    for name, like in likes.items():
        if like is None:
            out[name] = None
            continue
        if name not in manifest["trees"]:
            raise KeyError(f"bundle step {step} has no tree {name!r} "
                           f"(has {manifest['trees']})")
        out[name], _ = _restore_dir(os.path.join(d, name), like)
    return out, manifest["extra"]


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None):
        self._launch(lambda t: save(self.ckpt_dir, step, t, extra, self.keep),
                     tree)

    def save_bundle(self, step: int, trees: dict[str, Any],
                    extra: dict | None = None):
        """Async atomic multi-tree commit (params + opt in one step)."""
        self._launch(
            lambda t: save_bundle(self.ckpt_dir, step, t, extra, self.keep),
            trees)

    def _launch(self, fn, tree):
        self.wait()
        host_tree = jax.device_get(tree)  # snapshot before training mutates

        def work():
            try:
                fn(host_tree)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
