"""Fault-tolerant checkpointing: atomic commits, retention, async writes,
mesh-independent restore (elastic resharding is layered on top in elastic.py).

Layout:
  <dir>/step_<n>.tmp/      while writing
  <dir>/step_<n>/          after atomic rename (commit point)
      manifest.json        {leaf path -> {file, shape, dtype}}, step, extra
      <i>.npy              one file per leaf (host-gathered global arrays)
  <dir>/latest             text file holding the newest committed step

Partially-written checkpoints (no manifest / bad sizes) are skipped on
restore, so a crash mid-save never poisons a restart.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in kp)
        for kp, _ in flat
    ]
    return paths, [l for _, l in flat], treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None,
         keep: int = 3) -> str:
    paths, leaves, _ = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{i}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][p] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # commit point
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(ckpt_dir, "latest.tmp"), os.path.join(ckpt_dir, "latest"))
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int):
    steps = sorted(committed_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def committed_steps(ckpt_dir: str):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            path = os.path.join(ckpt_dir, name, "manifest.json")
            if os.path.exists(path):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
    return out


def latest_step(ckpt_dir: str) -> int | None:
    """Newest *valid* checkpoint — prefers the `latest` pointer but falls back
    to a directory scan if the pointer is stale or the target is corrupt."""
    candidates = sorted(committed_steps(ckpt_dir), reverse=True)
    ptr = os.path.join(ckpt_dir, "latest")
    if os.path.exists(ptr):
        try:
            s = int(open(ptr).read().strip())
            if s in candidates and _valid(ckpt_dir, s):
                return s
        except (ValueError, OSError):
            pass
    for s in candidates:
        if _valid(ckpt_dir, s):
            return s
    return None


def _valid(ckpt_dir: str, step: int) -> bool:
    d = os.path.join(ckpt_dir, f"step_{step}")
    try:
        manifest = json.load(open(os.path.join(d, "manifest.json")))
    except (OSError, json.JSONDecodeError):
        return False
    for meta in manifest["leaves"].values():
        f = os.path.join(d, meta["file"])
        if not os.path.exists(f):
            return False
    return True


def restore(ckpt_dir: str, step: int, like: Any) -> tuple[Any, dict]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). Returns (tree, extra)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    paths, leaves, treedef = _flatten(like)
    out = []
    for p, leaf in zip(paths, leaves):
        meta = manifest["leaves"][p]
        arr = np.load(os.path.join(d, meta["file"]))
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {p}: {arr.shape} vs {want}")
        out.append(arr.astype(getattr(leaf, "dtype", arr.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in flight at a time)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()
        host_tree = jax.device_get(tree)  # snapshot before training mutates

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra, self.keep)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
