"""Cluster health: heartbeats, failure detection, straggler policy.

Single-process control-plane logic (the data plane is JAX): a coordinator
tracks per-host heartbeats and step-completion times; hosts that miss
``timeout`` are declared dead and their data shards reassigned
deterministically (see data/pipeline.reassign_shard — the replacement
regenerates the identical stream). A dead host that heartbeats again is
*revived*: its shard reassignment is retracted so exactly one host generates
each stream. Stragglers are flagged by comparing each host's RECENT
completion-time window against the cross-host median of the same windows —
one GC pause cannot flag a healthy host (the window median absorbs it), and a
slowly-degrading host is judged against its peers, not its own old samples.
The straggler hook is a re-shard recommendation; in a real deployment this
drives the scheduler.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable


def _median(xs) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


@dataclasses.dataclass
class HostState:
    last_heartbeat: float
    step_times: deque
    alive: bool = True


class HealthMonitor:
    def __init__(self, hosts: list[int], timeout: float = 60.0,
                 straggler_factor: float = 2.0, window: int = 16,
                 recent: int = 4, min_samples: int = 3,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self.recent = recent  # per-host comparison window (last N step times)
        self.min_samples = min_samples  # hosts with fewer samples are exempt
        self.clock = clock
        self.hosts = {
            h: HostState(last_heartbeat=clock(), step_times=deque(maxlen=window))
            for h in hosts
        }
        self.reassignments: dict[int, int] = {}  # dead shard -> replacement host

    def heartbeat(self, host: int, step_time: float | None = None):
        st = self.hosts[host]
        st.last_heartbeat = self.clock()
        if not st.alive:
            # revival: the host is generating its own stream again, so the
            # reassignment MUST be retracted — otherwise two hosts regenerate
            # the same shard (duplicate data in every global batch). Its
            # retained step times are from before the outage — a stale era
            # that would misread as straggling against peers' fresh windows.
            st.alive = True
            st.step_times.clear()
            self.reassignments.pop(host, None)
        if step_time is not None:
            st.step_times.append(step_time)

    def _recent_medians(self, now: float) -> dict[int, float]:
        """Per-host median of the last ``recent`` step times. Guards: alive,
        at least ``min_samples`` samples (tiny-sample guard), and a heartbeat
        within half the death timeout — a silent-but-not-yet-declared host's
        window is frozen in an older era (e.g. still holding warmup-slow
        steps its peers have aged out) and must not be read as straggling;
        it is on the death track, not the straggler track."""
        out = {}
        for h, st in self.hosts.items():
            if (st.alive and len(st.step_times) >= self.min_samples
                    and now - st.last_heartbeat <= self.timeout / 2):
                out[h] = _median(list(st.step_times)[-self.recent:])
        return out

    def check(self) -> dict:
        """Returns {'dead': [...], 'stragglers': [...], 'reassign': {shard: host}}."""
        now = self.clock()
        dead = []
        for h, st in self.hosts.items():
            if st.alive and now - st.last_heartbeat > self.timeout:
                st.alive = False
                dead.append(h)

        # stragglers: each alive host's recent-window median vs the cross-host
        # median of those same windows. Needs >= 2 comparable hosts — with one
        # host there is no peer baseline and nothing is flagged.
        stragglers = []
        recents = self._recent_medians(now)
        if len(recents) >= 2:
            cross = _median(recents.values())
            stragglers = [h for h, m in sorted(recents.items())
                          if m > self.straggler_factor * cross]

        survivors = sorted(h for h, s in self.hosts.items() if s.alive)
        # deterministic reassignment: dead shard -> lowest-id surviving host;
        # NEVER re-reassign a shard that already has a replacement (revival
        # retracts entries, so presence here means the host is still dead)
        reassign = {}
        for i, h in enumerate(sorted(dead)):
            if survivors and h not in self.reassignments:
                reassign[h] = survivors[i % len(survivors)]
        # re-route existing reassignments whose replacement has since died
        for h, repl in sorted(self.reassignments.items()):
            if survivors and not self.hosts[repl].alive:
                reassign[h] = survivors[0]
        self.reassignments.update(reassign)
        return {"dead": dead, "stragglers": stragglers, "reassign": reassign}
