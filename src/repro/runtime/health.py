"""Cluster health: heartbeats, failure detection, straggler policy.

Single-process control-plane logic (the data plane is JAX): a coordinator
tracks per-host heartbeats and step-completion times; hosts that miss
``timeout`` are declared dead and their data shards reassigned
deterministically (see data/pipeline.reassign_shard — the replacement
regenerates the identical stream). Stragglers (completion time > multiplier x
rolling median) trigger the mitigation hook — by default a re-shard
recommendation; in a real deployment this drives the scheduler.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable


@dataclasses.dataclass
class HostState:
    last_heartbeat: float
    step_times: deque
    alive: bool = True


class HealthMonitor:
    def __init__(self, hosts: list[int], timeout: float = 60.0,
                 straggler_factor: float = 2.0, window: int = 16,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self.clock = clock
        self.hosts = {
            h: HostState(last_heartbeat=clock(), step_times=deque(maxlen=window))
            for h in hosts
        }
        self.reassignments: dict[int, int] = {}  # dead shard -> replacement host

    def heartbeat(self, host: int, step_time: float | None = None):
        st = self.hosts[host]
        st.last_heartbeat = self.clock()
        st.alive = True
        if step_time is not None:
            st.step_times.append(step_time)

    def check(self) -> dict:
        """Returns {'dead': [...], 'stragglers': [...], 'reassign': {shard: host}}."""
        now = self.clock()
        dead, stragglers = [], []
        all_times = [t for s in self.hosts.values() if s.alive for t in s.step_times]
        median = sorted(all_times)[len(all_times) // 2] if all_times else None
        for h, st in self.hosts.items():
            if st.alive and now - st.last_heartbeat > self.timeout:
                st.alive = False
                dead.append(h)
            elif (
                st.alive
                and median is not None
                and st.step_times
                and st.step_times[-1] > self.straggler_factor * median
            ):
                stragglers.append(h)
        # deterministic reassignment: dead shard -> lowest-id surviving host
        survivors = sorted(h for h, s in self.hosts.items() if s.alive)
        reassign = {}
        for i, h in enumerate(sorted(dead)):
            if survivors:
                reassign[h] = survivors[i % len(survivors)]
        self.reassignments.update(reassign)
        return {"dead": dead, "stragglers": stragglers, "reassign": reassign}
