"""Elastic scaling: re-lay a checkpoint out on a different mesh.

Checkpoints store *global* arrays (mesh-independent), so elasticity reduces
to (a) rebuilding the mesh at the new size, (b) recomputing PartitionSpecs
from the same logical rules, (c) device_put with the new shardings, and
(d) rescaling data-pipeline shard assignments. Batch-size-invariant restarts
(same global batch, different host count) are exact; tests cover 8->4->8.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat
from repro.runtime import checkpoint as ckpt
from repro.sharding import rules


def make_mesh_for(devices=None, model_parallel: int = 1, pods: int = 1):
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    assert n % (model_parallel * pods) == 0
    data = n // (model_parallel * pods)
    if pods > 1:
        return compat.make_mesh((pods, data, model_parallel), ("pod", "data", "model"),
                                devices=devices)
    return compat.make_mesh((data, model_parallel), ("data", "model"),
                            devices=devices)


def resume_on_mesh(ckpt_dir: str, like_params, like_opt, cfg, mesh: Mesh):
    """Restore the latest checkpoint and place it on `mesh` with the logical
    sharding rules. Returns (params, opt_state, extra) or None if no ckpt."""
    step = ckpt.latest_step(ckpt_dir)
    if step is None:
        return None
    params_host, extra = ckpt.restore(ckpt_dir, step, like_params)
    opt_host, _ = ckpt.restore(ckpt_dir + "/opt", step, like_opt) if like_opt is not None else (None, None)

    pspecs = rules.param_pspecs(params_host, cfg, mesh)
    params = jax.device_put(params_host, rules.named(mesh, pspecs))
    opt_state = None
    if opt_host is not None:
        ospecs = rules.opt_pspecs(pspecs, params_host, mesh)
        # OptState = (step, m, v): step replicated, m/v follow opt specs
        from jax.sharding import NamedSharding, PartitionSpec as P

        opt_state = type(opt_host)(
            step=jax.device_put(opt_host.step, NamedSharding(mesh, P())),
            m=jax.device_put(opt_host.m, rules.named(mesh, ospecs)),
            v=None if opt_host.v is None else jax.device_put(opt_host.v, rules.named(mesh, ospecs)),
        )
    return params, opt_state, {"step": step, **extra}
