"""Elastic scaling: re-lay a checkpoint out on a different mesh.

Checkpoints store *global* arrays (mesh-independent), so elasticity reduces
to (a) rebuilding the mesh at the new size, (b) recomputing PartitionSpecs
from the same logical rules, (c) device_put with the new shardings, and
(d) rescaling data-pipeline shard assignments. Batch-size-invariant restarts
(same global batch, different host count) are exact; tests cover 8->4->8.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat
from repro.runtime import checkpoint as ckpt
from repro.sharding import rules


def make_mesh_for(devices=None, model_parallel: int = 1, pods: int = 1,
                  data_only: bool = False):
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    if model_parallel * pods <= 0 or n % (model_parallel * pods) != 0:
        raise ValueError(
            f"cannot lay {n} devices out as pods={pods} x data x "
            f"model_parallel={model_parallel}: {n} % {model_parallel * pods} != 0")
    if data_only:
        # pure-DP mesh with ONLY the data axis: shard_map over it is fully
        # manual, which host-callback strategies (switch_emu) require —
        # pure_callback rejects meshes with any automatic axis left over
        # (the elastic controller re-meshes with this).
        if model_parallel != 1 or pods != 1:
            raise ValueError("data_only mesh cannot carry model/pod axes")
        return compat.make_mesh((n,), ("data",), devices=devices)
    data = n // (model_parallel * pods)
    if pods > 1:
        return compat.make_mesh((pods, data, model_parallel), ("pod", "data", "model"),
                                devices=devices)
    return compat.make_mesh((data, model_parallel), ("data", "model"),
                            devices=devices)


def resume_on_mesh(ckpt_dir: str, like_params, like_opt, cfg, mesh: Mesh):
    """Restore the latest checkpoint and place it on `mesh` with the logical
    sharding rules. Returns (params, opt_state, extra) or None if no ckpt.

    Expects the atomic bundle layout (``checkpoint.save_bundle`` with
    ``params``/``opt`` trees — the only layout that guarantees both landed on
    the same step); single-tree steps restore params only."""
    step = ckpt.latest_step(ckpt_dir)
    if step is None:
        return None
    try:
        trees, extra = ckpt.restore_bundle(
            ckpt_dir, step, {"params": like_params, "opt": like_opt})
        params_host, opt_host = trees["params"], trees["opt"]
    except ValueError:  # legacy single-tree checkpoint: params only
        params_host, extra = ckpt.restore(ckpt_dir, step, like_params)
        opt_host = None

    pspecs = rules.param_pspecs(params_host, cfg, mesh)
    params = jax.device_put(params_host, rules.named(mesh, pspecs))
    opt_state = None
    if opt_host is not None:
        ospecs = rules.opt_pspecs(pspecs, params_host, mesh)
        # OptState = (step, m, v): step replicated, m/v follow opt specs
        from jax.sharding import NamedSharding, PartitionSpec as P

        opt_state = type(opt_host)(
            step=jax.device_put(opt_host.step, NamedSharding(mesh, P())),
            m=jax.device_put(opt_host.m, rules.named(mesh, ospecs)),
            v=None if opt_host.v is None else jax.device_put(opt_host.v, rules.named(mesh, ospecs)),
        )
    return params, opt_state, {"step": step, **extra}
