"""qwen1.5-0.5b [dense] — hf:Qwen/Qwen1.5-0.5B. QKV bias, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=2816, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6,
)

SMOKE = CONFIG.with_(
    name="qwen1.5-0.5b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=512,
    param_dtype="float32", activation_dtype="float32", attn_q_chunk=32,
)
