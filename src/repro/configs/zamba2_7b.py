"""zamba2-7b [hybrid] — arXiv:2411.15242. Mamba2 backbone + shared attention
block applied every 6 layers (13 applications, 3 tail mamba layers)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_chunk=256, hybrid_attn_every=6,
)

SMOKE = CONFIG.with_(
    name="zamba2-7b-smoke", num_layers=7, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=512,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=16, hybrid_attn_every=3,
    param_dtype="float32", activation_dtype="float32", attn_q_chunk=32,
)
