"""Model / run configuration dataclasses and the assigned input shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    mlp: str = "swiglu"  # swiglu | gelu

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_token: int = 0
    moe_dense_ff: int = 0  # arctic-style parallel dense residual FFN
    capacity_factor: float = 1.25
    moe_group_size: int = 512  # tokens per dispatch group

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_groups: int = 1  # B/C groups
    ssm_conv_width: int = 4

    # --- hybrid (zamba2): shared attention block applied every k ssm layers ---
    hybrid_attn_every: int = 0

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    num_frames: int = 1500  # stub conv-frontend output length (encoder input)

    # --- vlm (llava): stub patch-embedding prefix ---
    num_patches: int = 0

    # --- numerics / execution ---
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    remat: str = "full"  # none | full | dots
    tie_embeddings: bool = False
    attn_q_chunk: int = 2048  # flash-style q/kv chunking granularity

    # --- distribution ---
    dp_boundary: str = "replica"  # replica: FPISA over (pod,data); pod: over pod only
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    accum_steps: int = 1  # gradient-accumulation microbatches per step
    seq_parallel: bool = False  # Megatron-style SP: shard seq over 'model' between TP blocks
    flash_remat: bool = True  # remat the attention pair-step (recompute scores in bwd);
    # keep OFF for hdim-TP archs whose scores carry an all-reduce (it would re-run it)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k requires sub-quadratic attention: run only for SSM/hybrid archs.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.family in LONG_CONTEXT_FAMILIES
    return True
