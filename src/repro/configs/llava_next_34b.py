"""llava-next-34b [vlm] — anyres tiling backbone; patch embeddings stubbed
(input_specs supplies precomputed (B, 576, d_model) patch features)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000, num_patches=576,
    flash_remat=False,  # hdim TP: scores carry an AR; recompute would re-run it
)

SMOKE = CONFIG.with_(
    name="llava-next-34b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=512, num_patches=8,
    param_dtype="float32", activation_dtype="float32", attn_q_chunk=32,
)
