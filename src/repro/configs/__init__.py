"""Architecture config registry: ``get_config(name)`` / ``list_configs()``.

Each assigned architecture has a module defining ``CONFIG`` (the exact
published configuration) and ``SMOKE`` (a reduced same-family config for CPU
smoke tests).
"""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable

ARCH_MODULES = {
    "qwen1.5-0.5b": "qwen15_0_5b",
    "internlm2-20b": "internlm2_20b",
    "deepseek-67b": "deepseek_67b",
    "stablelm-3b": "stablelm_3b",
    "arctic-480b": "arctic_480b",
    "kimi-k2-1t-a32b": "kimi_k2",
    "zamba2-7b": "zamba2_7b",
    "llava-next-34b": "llava_next_34b",
    "whisper-medium": "whisper_medium",
    "mamba2-780m": "mamba2_780m",
}

ARCH_NAMES = list(ARCH_MODULES)


def _module(name: str):
    if name not in ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def list_configs():
    return {n: get_config(n) for n in ARCH_NAMES}
