"""deepseek-67b [dense] — arXiv:2401.02954. Llama-arch, 95L, GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=102400,
)

SMOKE = CONFIG.with_(
    name="deepseek-67b-smoke", num_layers=3, d_model=64, num_heads=8,
    num_kv_heads=2, d_ff=128, vocab_size=512,
    param_dtype="float32", activation_dtype="float32", attn_q_chunk=32,
)
