"""whisper-medium [audio] — arXiv:2212.04356. Enc-dec; conv frontend stubbed
(input_specs supplies precomputed (B, 1500, d_model) frame embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=51865, mlp="gelu",
    is_encoder_decoder=True, num_encoder_layers=24, num_frames=1500,
)

SMOKE = CONFIG.with_(
    name="whisper-medium-smoke", num_layers=2, num_encoder_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512,
    num_frames=24,
    param_dtype="float32", activation_dtype="float32", attn_q_chunk=32,
)
