"""mamba2-780m [ssm] — arXiv:2405.21060 (SSD). Attention-free."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_chunk=256,
)

SMOKE = CONFIG.with_(
    name="mamba2-780m-smoke", num_layers=2, d_model=64,
    vocab_size=512, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
    param_dtype="float32", activation_dtype="float32",
)
