"""arctic-480b [moe] — hf:Snowflake/snowflake-arctic-base.

128 experts top-2 with a parallel dense residual MLP per layer."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    num_experts=128, num_experts_per_token=2, moe_dense_ff=4864,
    dp_boundary="pod",
    flash_remat=False,  # hdim TP: scores carry an AR; recompute would re-run it
)

SMOKE = CONFIG.with_(
    name="arctic-480b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=512,
    num_experts=4, num_experts_per_token=2, moe_dense_ff=64, moe_group_size=64,
    param_dtype="float32", activation_dtype="float32", attn_q_chunk=32,
)
