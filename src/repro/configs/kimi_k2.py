"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8 (paper-table)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    num_experts=384, num_experts_per_token=8,
    dp_boundary="pod",
)

SMOKE = CONFIG.with_(
    name="kimi-k2-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=64, vocab_size=512,
    num_experts=8, num_experts_per_token=2, moe_group_size=64,
    param_dtype="float32", activation_dtype="float32", attn_q_chunk=32,
)
