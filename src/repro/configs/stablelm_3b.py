"""stablelm-3b [dense] — hf:stabilityai/stablelm-2 family."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=6912, vocab_size=50304,
)

SMOKE = CONFIG.with_(
    name="stablelm-3b-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=512,
    param_dtype="float32", activation_dtype="float32", attn_q_chunk=32,
)
