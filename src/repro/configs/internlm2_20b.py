"""internlm2-20b [dense] — arXiv:2403.17297. GQA kv=8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92544, rope_theta=1e6,
)

SMOKE = CONFIG.with_(
    name="internlm2-20b-smoke", num_layers=2, d_model=64, num_heads=8,
    num_kv_heads=2, d_ff=128, vocab_size=512,
    param_dtype="float32", activation_dtype="float32", attn_q_chunk=32,
)
