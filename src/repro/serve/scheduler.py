"""Continuous-batching scheduler over a paged KV cache.

The static :class:`~repro.serve.engine.ServeEngine` packs requests into
lockstep batches: every batch prefills together (left-padded to the batch
max) and the batch occupies its dense ``(b, max_len)`` cache until the
longest slot finishes. This engine replaces that with request-level
scheduling in the MaxText ``offline_inference.py`` shape:

- a fixed pool of ``num_slots`` decode slots; queued requests are admitted
  into free slots as soon as one opens (admission also reserves worst-case
  KV pages — no admitted request can ever hit OOM mid-decode, exhaustion
  shows up as queue backpressure instead);
- prefill runs SEPARATELY from the running decode batch: newly admitted
  prompts are prefilled unpadded (same-length prompts packed into one
  prefill call), their K/V copied into pages, and their first token taken
  from the prefill logits — the decode batch never stalls on a prompt;
- one decode step advances ALL live slots through
  ``model.decode_step_paged`` (per-slot positions, per-slot page tables);
  a slot is retired the moment its request finishes, freeing its pages and
  its slot for the next queued request;
- per-request TTFT/TPOT latencies are emitted in scheduler-step units
  (1 step == one decode iteration), plus wall-clock run time for goodput.

Bit-identity contract: greedy per-request outputs equal the static engine's
token for token (the static engine run per request is the oracle; see
DESIGN.md §11 for why unpadded prefill + paged decode preserves every bit).

Telemetry rides the ONE :class:`~repro.core.agg.Aggregator` facade
(``TelemetryChannel``): per-retirement rows of [requests, tokens, decode
steps, rejections] reduced over the data axis — including over a shared
multi-tenant dataplane when the config carries ``switch_shared``.
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from collections import deque
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import trace as _trace
from repro.core.agg import AggConfig
from repro.serve.engine import Request, Result, TelemetryChannel
from repro.serve.kvcache import PagedKVCache, pages_needed

__all__ = ["ContinuousEngine", "RequestStats"]


# ----------------------------------------------------------------------
# fused device programs
# ----------------------------------------------------------------------
# One jitted call per scheduler event, shared across engine instances: the
# model's bound functions ride along as static args, so a fresh engine over
# the same model hits the same trace/compile cache (benchmarks warm one
# engine and time another). Both programs fold the greedy argmax INTO the
# jitted body — one dispatch per event and a (b,) int32 result instead of
# full logits — and DONATE the KV pools, so XLA updates them in place
# instead of copying ~the whole cache on every call. The greedy retirement
# schedule is value-independent (fixed budgets, no stop token), so the
# token feedback loop never has to touch the host: ``nxt`` feeds straight
# back into the next decode and host materialization waits until
# retirement (see ``ContinuousEngine._tok``).


@partial(jax.jit, static_argnums=(0,), donate_argnums=(3, 4))
def _decode_fused(decode_fn, p, toks, k_pool, v_pool, table, lens):
    logits, k_pool, v_pool = decode_fn(p, toks, k_pool, v_pool, table, lens)
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    return nxt[:, None], k_pool, v_pool


# NB: ``nxt`` (argnum 8) is NOT donated — that buffer is the previous decode
# step's output and lives in the step history until every slot that
# referenced it retires.
@partial(jax.jit, static_argnums=(0, 1), donate_argnums=(5, 6))
def _prefill_fused(prefill_fn, page, p, toks, cache, k_pool, v_pool, pages,
                   nxt, rows):
    """Prefill a same-length group unpadded, scatter its K/V into the
    group's pages, and splice the first tokens into the decode feedback
    vector — one device call per admission group."""
    logits, cache = prefill_fn(p, {"tokens": toks}, cache)
    first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    k, v = cache.kv.k, cache.kv.v                    # (L, n, s, K, hd)
    L, n, s = k.shape[0], k.shape[1], k.shape[2]
    npg = -(-s // page)
    pad = npg * page - s
    if pad:
        padw = [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)]
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    kp = k.reshape(L, n * npg, page, *k.shape[3:])
    vp = v.reshape(L, n * npg, page, *v.shape[3:])
    k_pool = k_pool.at[:, pages].set(kp.astype(k_pool.dtype))
    v_pool = v_pool.at[:, pages].set(vp.astype(v_pool.dtype))
    return first, k_pool, v_pool, nxt.at[rows, 0].set(first)


@dataclasses.dataclass
class RequestStats:
    """Per-request serving latencies, in scheduler-step time units."""
    rid: int
    t_arrival: float
    t_admitted: float = math.nan
    t_first_token: float = math.nan
    t_finish: float = math.nan
    n_prompt: int = 0
    n_generated: int = 0

    @property
    def ttft(self) -> float:
        """Time to first token: queueing delay + prefill (prefill costs the
        step it happens in)."""
        return self.t_first_token - self.t_arrival

    @property
    def tpot(self) -> float:
        """Time per output token after the first (nan for 1-token requests)."""
        if self.n_generated <= 1:
            return math.nan
        return (self.t_finish - self.t_first_token) / (self.n_generated - 1)


@dataclasses.dataclass
class _Slot:
    req: Request
    budget: int          # effective max_new_tokens (post-admission)
    cache_len: int       # tokens currently in the paged cache
    reserved_pages: int  # worst-case pages charged at admission
    # generated tokens as (step-id, flat index) refs into the on-device
    # step history — materialized to host ints only at retirement, so the
    # decode loop never blocks on a device->host sync
    tokens: List[Tuple[int, int]]


class ContinuousEngine:
    """Throughput-first serving engine: continuous batching + paged KV.

    Same admission semantics as the static engine (over-long / empty prompts
    rejected, over-budget requests truncated to what the cache fits) so the
    two engines see identical effective workloads; additionally a request
    whose worst case exceeds the whole page pool is rejected up front, and a
    request that fits *eventually* but not *now* simply waits in the queue
    (backpressure, never OOM).
    """

    def __init__(self, model, params, num_slots: int, max_len: int,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 agg: AggConfig | None = None, mesh=None,
                 max_prefill_per_step: Optional[int] = None):
        if model.decode_step_paged is None:
            raise ValueError(
                f"model family {model.cfg.family!r} has no paged decode path; "
                f"use the static ServeEngine")
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.cache = PagedKVCache(model.cfg, num_slots, max_len, page_size,
                                  num_pages=num_pages)
        self._decode = partial(_decode_fused, model.decode_step_paged)
        self._prefill = partial(_prefill_fused, model.prefill,
                                self.cache.page_size)
        self._next = jnp.zeros((num_slots, 1), jnp.int32)
        self._hist: Dict[int, object] = {}     # step id -> device tokens
        self._hist_np: Dict[int, np.ndarray] = {}
        self._sid = 0
        self.max_prefill_per_step = max_prefill_per_step or num_slots
        self.slots: List[Optional[_Slot]] = [None] * num_slots
        self.queue: deque[Tuple[float, Request]] = deque()
        self.now = 0.0
        self.stats: Dict[int, RequestStats] = {}
        self._reserved_total = 0
        self.telemetry = {
            "requests": 0, "tokens_generated": 0, "decode_steps": 0,
            "prefills": 0, "prefill_tokens": 0, "rejected": 0,
            "truncated": 0, "admitted": 0, "retired": 0, "queue_peak": 0,
            "slot_steps": 0,
        }
        self.telemetry_channel = None
        if agg is not None:
            # [requests, tokens, decode steps, rejections] per flush window
            self.telemetry_channel = TelemetryChannel(agg, ncols=4, mesh=mesh)
        self._window = {"rows": [], "decode_steps": 0, "rejected": 0}

    @property
    def aggregator(self):
        ch = self.telemetry_channel
        return None if ch is None else ch.aggregator

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, req: Request, t_arrival: Optional[float] = None) -> bool:
        """Queue a request (admission-checked). Returns False if rejected."""
        t = self.now if t_arrival is None else t_arrival
        r = self._check(req)
        if r is None:
            return False
        self.stats[r.rid] = RequestStats(rid=r.rid, t_arrival=t,
                                         n_prompt=len(r.prompt))
        self.queue.append((t, r))
        self.telemetry["queue_peak"] = max(self.telemetry["queue_peak"],
                                           len(self.queue))
        return True

    def run(self, requests: Sequence[Request]) -> List[Result]:
        """Serve a closed batch of requests all arriving at t=0."""
        return self.run_trace([(0.0, r) for r in requests])

    def run_trace(self, arrivals: Sequence[Tuple[float, Request]]
                  ) -> List[Result]:
        """Serve a timed trace of (arrival_time, request) pairs (time in
        scheduler-step units, e.g. from ``repro.serve.loadgen``). Returns
        results in COMPLETION order; per-request latencies land in
        ``self.stats[rid]``. Wall-clock run time lands in
        ``self.last_wall_s``."""
        pending = deque(sorted(arrivals, key=lambda a: a[0]))
        results: List[Result] = []
        t0 = time.perf_counter()
        guard = 0
        limit = 16 * (len(pending) + 1) * (self.max_len + 2)
        while pending or self.queue or any(self.slots):
            guard += 1
            if guard > limit:  # pragma: no cover - scheduler invariant
                raise RuntimeError("scheduler failed to drain the trace")
            while pending and pending[0][0] <= self.now:
                t, r = pending.popleft()
                self.submit(r, t)
            results.extend(self._admit_from_queue())
            if not any(self.slots):
                if self.queue:
                    # backpressure with idle slots cannot deadlock: pages are
                    # only held by live slots, and _check caps worst cases at
                    # the pool size — so an empty slot table means the queue
                    # head is admissible next iteration.
                    continue
                if pending:
                    self.now = max(self.now + 1.0,
                                   float(math.ceil(pending[0][0])))
                    continue
                break
            results.extend(self._decode_step())
        self._flush_telemetry()
        self._hist.clear()       # all slots retired: history fully drained
        self._hist_np.clear()
        self.last_wall_s = time.perf_counter() - t0
        return results

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def _check(self, r: Request) -> Optional[Request]:
        """Static-engine admission semantics + a whole-pool feasibility
        check; returns the (possibly truncated) request or None."""
        plen = len(r.prompt)
        if plen == 0:
            warnings.warn(f"request {r.rid}: zero-length prompt; rejected")
            self._reject()
            return None
        if plen > self.max_len:
            warnings.warn(
                f"request {r.rid}: prompt length {plen} exceeds engine "
                f"max_len={self.max_len}; rejected")
            self._reject()
            return None
        fit = self.max_len - plen + 1
        if r.max_new_tokens > fit:
            warnings.warn(
                f"request {r.rid}: max_new_tokens={r.max_new_tokens} "
                f"does not fit the KV cache after a {plen}-token prompt; "
                f"truncated to {fit}")
            self.telemetry["truncated"] += 1
            r = dataclasses.replace(r, max_new_tokens=fit)
        if self._worst_case_pages(plen, r.max_new_tokens) > \
                self.cache.allocator.num_pages:
            warnings.warn(
                f"request {r.rid}: needs more KV pages than the whole pool "
                f"({self.cache.allocator.num_pages}); rejected")
            self._reject()
            return None
        return r

    def _reject(self):
        self.telemetry["rejected"] += 1
        self._window["rejected"] += 1

    def _worst_case_pages(self, plen: int, budget: int) -> int:
        # positions used: prompt [0, plen) plus budget-1 decode writes
        # (the first generated token rides the prefill logits)
        return pages_needed(plen + budget - 1, self.cache.page_size)

    def _admit_from_queue(self) -> List[Result]:
        """Admit queue-head requests into free slots while both a slot and
        the worst-case page reservation are available (FIFO — no head-of-
        line bypass, so admission order is deterministic). Same-length
        prompts admitted in the same step share one packed prefill call.
        Returns results for requests whose budget is 1 (their single token
        rides the prefill — they retire without ever entering the decode
        batch)."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        picked: List[Tuple[int, float, Request]] = []
        while (free and self.queue
               and len(picked) < self.max_prefill_per_step):
            t_arr, r = self.queue[0]
            wc = self._worst_case_pages(len(r.prompt), r.max_new_tokens)
            if self._reserved_total + wc > self.cache.allocator.num_pages:
                break  # backpressure: head waits for pages to free up
            self.queue.popleft()
            slot = free.pop(0)
            self._reserved_total += wc
            picked.append((slot, t_arr, r))
        results: List[Result] = []
        if not picked:
            return results
        # pack prefills by prompt length: identical lengths need no padding,
        # so a packed (n, s) prefill stays bit-identical per row
        by_len: Dict[int, List[Tuple[int, float, Request]]] = {}
        for slot, t_arr, r in picked:
            by_len.setdefault(len(r.prompt), []).append((slot, t_arr, r))
        for plen, group in sorted(by_len.items()):
            results.extend(self._prefill_group(plen, group))
        return results

    def _prefill_group(self, plen: int,
                       group: List[Tuple[int, float, Request]]) -> List[Result]:
        n = len(group)
        toks = np.stack([r.prompt for _, _, r in group]).astype(np.int32)
        npg = pages_needed(plen, self.cache.page_size)
        rows, pages = [], []
        for slot, _, r in group:
            ok = self.cache.grow_slot(slot, plen)
            assert ok, "reservation accounting must cover the prompt pages"
            rows.append(slot)
            pages.extend(self.cache.slot_pages(slot)[:npg])
        cache = self.model.init_cache(n, plen)
        with _trace.span("serve.prefill", phase="prefill", n=n,
                         plen=plen, elems=n * plen) as sp:
            first, self.cache.k, self.cache.v, self._next = self._prefill(
                self.params, jnp.asarray(toks), cache, self.cache.k,
                self.cache.v, jnp.asarray(np.asarray(pages, np.int32)),
                self._next, jnp.asarray(np.asarray(rows, np.int32)))
            sp.sync(first)
        sid = self._sid
        self._sid += 1
        self._hist[sid] = first
        self.telemetry["prefills"] += 1
        self.telemetry["prefill_tokens"] += n * plen
        results: List[Result] = []
        for i, (slot, t_arr, r) in enumerate(group):
            st = self.stats[r.rid]
            st.t_admitted = self.now
            st.t_first_token = self.now
            s = _Slot(req=r, budget=r.max_new_tokens, cache_len=plen,
                      reserved_pages=self._worst_case_pages(plen,
                                                            r.max_new_tokens),
                      tokens=[(sid, i)])
            self.telemetry["admitted"] += 1
            if s.budget == 1:
                # single-token request: its one token rode the prefill
                # logits — it retires without entering the decode batch
                results.append(self._retire(slot, s))
            else:
                self.slots[slot] = s
        return results

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    def _decode_step(self) -> List[Result]:
        """One lockstep decode over every live slot (idle slots ride along
        pointed at the scratch page; their logits are discarded). The next
        input token comes straight off the previous step's on-device argmax
        (``self._next``) — no host round-trip in the loop."""
        active = [i for i, s in enumerate(self.slots) if s is not None]
        lens = np.zeros((self.num_slots,), np.int32)
        for i in active:
            s = self.slots[i]
            ok = self.cache.grow_slot(i, s.cache_len + 1)
            assert ok, "reservation accounting must cover decode growth"
            lens[i] = s.cache_len
        with _trace.span("serve.decode", phase="decode",
                         active=len(active)) as sp:
            self._next, self.cache.k, self.cache.v = self._decode(
                self.params, self._next, self.cache.k, self.cache.v,
                self.cache.device_table(), jnp.asarray(lens))
            sp.sync(self._next)
        sid = self._sid
        self._sid += 1
        self._hist[sid] = self._next
        self.now += 1.0
        self.telemetry["decode_steps"] += 1
        self.telemetry["slot_steps"] += len(active)
        self._window["decode_steps"] += 1
        results: List[Result] = []
        for i in active:
            s = self.slots[i]
            s.cache_len += 1
            s.tokens.append((sid, i))
            if len(s.tokens) >= s.budget:
                results.append(self._retire(i, s))
                self.slots[i] = None
        return results

    # ------------------------------------------------------------------
    # retirement + telemetry
    # ------------------------------------------------------------------

    def _tok(self, sid: int, idx: int) -> int:
        """Materialize one generated token from the on-device step history
        (each step's (b,) token vector syncs to host at most once)."""
        buf = self._hist_np.get(sid)
        if buf is None:
            buf = np.asarray(self._hist[sid]).ravel()
            self._hist_np[sid] = buf
        return int(buf[idx])

    def _retire(self, slot: int, s: _Slot) -> Result:
        self.cache.release_slot(slot)
        self._reserved_total -= s.reserved_pages
        st = self.stats[s.req.rid]
        st.t_finish = self.now
        st.n_generated = len(s.tokens)
        self.telemetry["retired"] += 1
        res = Result(rid=s.req.rid,
                     tokens=np.asarray([self._tok(sid, i)
                                        for sid, i in s.tokens], np.int32))
        if self.telemetry_channel is None:
            self.telemetry["requests"] += 1
            self.telemetry["tokens_generated"] += len(res.tokens)
        else:
            self._window["rows"].append((1.0, float(len(res.tokens))))
            if len(self._window["rows"]) >= self.num_slots:
                self._flush_telemetry()
        return res

    def _flush_telemetry(self):
        """Push the window's [requests, tokens, decode steps, rejections]
        through the facade (when configured) and fold into the totals —
        every retirement window is one facade reduction, the serving-path
        analogue of a per-batch gradient aggregation."""
        w = self._window
        if self.telemetry_channel is None:
            return
        if not (w["rows"] or w["decode_steps"] or w["rejected"]):
            return
        rows = [(nreq, ntok, 0.0, 0.0) for nreq, ntok in w["rows"]]
        rows.append((0.0, 0.0, float(w["decode_steps"]), float(w["rejected"])))
        n_req, n_tok, _steps, _rej = self.telemetry_channel.reduce(rows)
        self.telemetry["requests"] += n_req
        self.telemetry["tokens_generated"] += n_tok
        self._window = {"rows": [], "decode_steps": 0, "rejected": 0}

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def latency_stats(self) -> List[RequestStats]:
        return [st for st in self.stats.values()
                if not math.isnan(st.t_finish)]
