"""Batched serving engine: continuous prefill+decode over a request queue.

Production shape: requests arrive with prompts, get packed into a fixed batch
with per-slot position tracking; a jitted prefill fills a fresh slot's cache
region and a jitted decode step advances all active slots. Slot caches are
per-request here (simple static batching); the dry-run decode shapes exercise
the same decode_step the engine uses.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Result:
    rid: int
    tokens: np.ndarray


class ServeEngine:
    """Static-batch engine: groups requests into batches of `batch_size`,
    prefills them together, then decodes greedily until all finish."""

    def __init__(self, model, params, batch_size: int, max_len: int,
                 sampler: str = "greedy"):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def run(self, requests: List[Request]) -> List[Result]:
        out: List[Result] = []
        for i in range(0, len(requests), self.batch_size):
            out.extend(self._run_batch(requests[i : i + self.batch_size]))
        return out

    def _run_batch(self, reqs: List[Request]) -> List[Result]:
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for j, r in enumerate(reqs):
            toks[j, plen - len(r.prompt):] = r.prompt  # left-pad
        cache = self.model.init_cache(b, self.max_len)
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch, cache)
        new = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        gen = [new]
        steps = max(r.max_new_tokens for r in reqs) - 1
        for _ in range(steps):
            logits, cache = self._decode(self.params, new, cache)
            new = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            gen.append(new)
        gen_np = np.concatenate([np.asarray(g) for g in gen], axis=1)
        return [
            Result(rid=r.rid, tokens=gen_np[j, : r.max_new_tokens])
            for j, r in enumerate(reqs)
        ]
