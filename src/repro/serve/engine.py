"""Batched serving engine: continuous prefill+decode over a request queue.

Production shape: requests arrive with prompts, get packed into a fixed batch
with per-slot position tracking; a jitted prefill fills a fresh slot's cache
region and a jitted decode step advances all active slots. Slot caches are
per-request here (simple static batching); the dry-run decode shapes exercise
the same decode_step the engine uses.

Aggregation facade: the engine accepts the same ``AggConfig`` as the training
stack (``repro.core.agg``). When given, per-batch serving telemetry (request
and generated-token counts) is reduced across the data axis through ONE
:class:`~repro.core.agg.Aggregator` — the in-network aggregation point the
paper also targets for telemetry/queries (cf. ``db/query.py``) — so the
serving path exercises exactly the facade the trainers use, and a typo'd
``--agg-strategy`` fails at engine construction with the registered options,
not mid-request.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.agg import AggConfig, Aggregator


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Result:
    rid: int
    tokens: np.ndarray


class ServeEngine:
    """Static-batch engine: groups requests into batches of `batch_size`,
    prefills them together, then decodes greedily until all finish."""

    def __init__(self, model, params, batch_size: int, max_len: int,
                 sampler: str = "greedy", agg: AggConfig | None = None,
                 mesh=None):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        # telemetry aggregated through the facade (module doc): totals of
        # [requests, generated tokens] reduced over the data axis per batch
        self.telemetry = {"requests": 0, "tokens_generated": 0, "batches": 0,
                          "decode_steps": 0, "rejected": 0, "truncated": 0}
        self.aggregator = None
        if agg is not None:
            self._mesh = mesh or compat.make_mesh(
                (jax.device_count(),), ("data",))
            # the ONE facade instance for the serving path — strategy/backend
            # lookup and capability validation happen here, at engine build
            self.aggregator = Aggregator(agg, ("data",))
            self._agg_telemetry = jax.jit(compat.shard_map(
                lambda rows: self.aggregator.allreduce(rows[0]),
                mesh=self._mesh, in_specs=P("data", None), out_specs=P(),
                check_vma=False))

    def run(self, requests: List[Request]) -> List[Result]:
        admitted = self._admit(requests)
        out: List[Result] = []
        for i in range(0, len(admitted), self.batch_size):
            out.extend(self._run_batch(admitted[i : i + self.batch_size]))
        return out

    def _admit(self, requests: List[Request]) -> List[Request]:
        """KV-cache admission control: the cache is sized ``init_cache(b,
        max_len)``, and a slot consumes ``len(prompt)`` positions at prefill
        plus one per decode step (the first generated token rides the prefill
        logits, costing no extra write). A request whose prompt alone
        exceeds ``max_len`` is refused; one whose prompt fits but whose
        ``max_new_tokens`` would run past the cache is truncated to the
        ``max_len - len(prompt) + 1`` tokens that fit, with a warning.
        Without this, over-length requests silently clobber the last cache
        position and corrupt every later decode step in the batch."""
        admitted: List[Request] = []
        for r in requests:
            plen = len(r.prompt)
            if plen > self.max_len:
                warnings.warn(
                    f"request {r.rid}: prompt length {plen} exceeds engine "
                    f"max_len={self.max_len}; rejected")
                self.telemetry["rejected"] += 1
                continue
            fit = self.max_len - plen + 1
            if r.max_new_tokens > fit:
                warnings.warn(
                    f"request {r.rid}: max_new_tokens={r.max_new_tokens} "
                    f"does not fit the KV cache after a {plen}-token prompt; "
                    f"truncated to {fit}")
                self.telemetry["truncated"] += 1
                r = dataclasses.replace(r, max_new_tokens=fit)
            admitted.append(r)
        return admitted

    def _record_telemetry(self, reqs: List[Request], results: List[Result]):
        """Fold one batch into the running totals — through the aggregation
        facade when configured (each data-axis shard contributes its share of
        the batch, exactly like gradient shards), host-side otherwise."""
        n_req = len(reqs)
        n_tok = sum(len(r.tokens) for r in results)
        if self.aggregator is not None:
            d = self._mesh.devices.size
            rows = np.zeros((d, 2), np.float32)
            for j in range(n_req):  # request j's stats live on shard j % d
                rows[j % d] += (1.0, len(results[j].tokens))
            agg_req, agg_tok = np.asarray(self._agg_telemetry(jnp.asarray(rows)))
            # round, don't truncate: narrow-wire strategies quantize (8.0 can
            # come back 7.9999995) and int() would undercount permanently
            n_req, n_tok = int(round(float(agg_req))), int(round(float(agg_tok)))
        self.telemetry["requests"] += n_req
        self.telemetry["tokens_generated"] += n_tok
        self.telemetry["batches"] += 1

    def _run_batch(self, reqs: List[Request]) -> List[Result]:
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for j, r in enumerate(reqs):
            toks[j, plen - len(r.prompt):] = r.prompt  # left-pad
        cache = self.model.init_cache(b, self.max_len)
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch, cache)
        new = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        gen = [new]
        # every slot's cache region starts at the BATCH prompt length
        # (left-padding): slot j can hold at most max_len - plen + 1 tokens
        # however generous its own admission-time budget was
        effs = [min(r.max_new_tokens, self.max_len - plen + 1) for r in reqs]
        # stop as soon as every slot holds its budget — not after the raw
        # max(max_new_tokens), which overruns the cache for packed batches
        while len(gen) < max(effs):
            logits, cache = self._decode(self.params, new, cache)
            new = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            gen.append(new)
        self.telemetry["decode_steps"] += len(gen) - 1
        gen_np = np.concatenate([np.asarray(g) for g in gen], axis=1)
        results = [
            Result(rid=r.rid, tokens=gen_np[j, : effs[j]])
            for j, r in enumerate(reqs)
        ]
        self._record_telemetry(reqs, results)
        return results
