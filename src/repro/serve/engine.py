"""Batched serving engine: static prefill+decode batches over a request queue.

Production shape: requests arrive with prompts, get packed into a fixed batch
with per-slot position tracking; a jitted prefill fills a fresh slot's cache
region and a jitted decode step advances all active slots. Slot caches are
per-request here (simple static batching); the continuous-batching engine in
``repro.serve.scheduler`` replaces the lockstep batch with slot-level
admission and a paged KV cache, and uses THIS engine as its bit-identity
oracle (greedy per-request outputs must match token for token).

Aggregation facade: the engine accepts the same ``AggConfig`` as the training
stack (``repro.core.agg``). When given, per-batch serving telemetry (request
and generated-token counts) is reduced across the data axis through ONE
:class:`~repro.core.agg.Aggregator` — the in-network aggregation point the
paper also targets for telemetry/queries (cf. ``db/query.py``) — so the
serving path exercises exactly the facade the trainers use, and a typo'd
``--agg-strategy`` fails at engine construction with the registered options,
not mid-request. :class:`TelemetryChannel` is the shared implementation both
engines route through.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.agg import AggConfig, Aggregator


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Result:
    rid: int
    tokens: np.ndarray


class TelemetryChannel:
    """Facade-backed serving telemetry: rows of per-request counters reduced
    over the data axis through ONE :class:`Aggregator` — shard ``j % d``
    contributes request j's counters, exactly like gradient shards. Shared by
    the static and continuous engines (and by multi-tenant serving: an
    ``AggConfig(switch_shared=...)`` routes these reductions over the shared
    dataplane the training jobs use)."""

    def __init__(self, agg: AggConfig, ncols: int, mesh=None):
        self.ncols = ncols
        self.mesh = mesh or compat.make_mesh((jax.device_count(),), ("data",))
        # the ONE facade instance for this serving path — strategy/backend
        # lookup and capability validation happen here, at engine build
        self.aggregator = Aggregator(agg, ("data",))
        self._reduce = jax.jit(compat.shard_map(
            lambda rows: self.aggregator.allreduce(rows[0]),
            mesh=self.mesh, in_specs=P("data", None), out_specs=P(),
            check_vma=False))

    def reduce(self, per_request_rows: Sequence[Sequence[float]]) -> List[int]:
        """Reduce a batch of per-request counter rows to global totals."""
        d = self.mesh.devices.size
        rows = np.zeros((d, self.ncols), np.float32)
        for j, r in enumerate(per_request_rows):
            rows[j % d] += np.asarray(r, np.float32)
        totals = np.asarray(self._reduce(jnp.asarray(rows)))
        # round, don't truncate: narrow-wire strategies quantize (8.0 can
        # come back 7.9999995) and int() would undercount permanently
        return [int(round(float(t))) for t in totals]


class ServeEngine:
    """Static-batch engine: groups requests into batches of `batch_size`,
    prefills them together, then decodes greedily until all finish. Finished
    slots are RETIRED from the lockstep batch (the decode batch shrinks to
    the still-live slots), so a batch mixing 4- and 64-token budgets no
    longer decodes every slot to the max — per-slot work stops at that
    slot's own budget."""

    def __init__(self, model, params, batch_size: int, max_len: int,
                 sampler: str = "greedy", agg: AggConfig | None = None,
                 mesh=None):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        # telemetry aggregated through the facade (module doc): totals of
        # [requests, generated tokens] reduced over the data axis per batch
        self.telemetry = {"requests": 0, "tokens_generated": 0, "batches": 0,
                          "decode_steps": 0, "rejected": 0, "truncated": 0,
                          "truncated_by_packing": 0, "slot_steps": 0}
        self.telemetry_channel = None
        if agg is not None:
            self.telemetry_channel = TelemetryChannel(agg, ncols=2, mesh=mesh)

    @property
    def aggregator(self):
        ch = self.telemetry_channel
        return None if ch is None else ch.aggregator

    def run(self, requests: List[Request]) -> List[Result]:
        admitted = self._admit(requests)
        out: List[Result] = []
        for i in range(0, len(admitted), self.batch_size):
            out.extend(self._run_batch(admitted[i : i + self.batch_size]))
        return out

    def _admit(self, requests: List[Request]) -> List[Request]:
        """KV-cache admission control: the cache is sized ``init_cache(b,
        max_len)``, and a slot consumes ``len(prompt)`` positions at prefill
        plus one per decode step (the first generated token rides the prefill
        logits, costing no extra write). A request whose prompt alone
        exceeds ``max_len`` — or is empty (nothing to prefill: the flash
        q/kv chunking divides by the sequence length) — is refused; one
        whose prompt fits but whose ``max_new_tokens`` would run past the
        cache is truncated to the ``max_len - len(prompt) + 1`` tokens that
        fit, with a warning. Without this, over-length requests silently
        clobber the last cache position and corrupt every later decode step
        in the batch."""
        admitted: List[Request] = []
        for r in requests:
            plen = len(r.prompt)
            if plen == 0:
                warnings.warn(
                    f"request {r.rid}: zero-length prompt; rejected")
                self.telemetry["rejected"] += 1
                continue
            if plen > self.max_len:
                warnings.warn(
                    f"request {r.rid}: prompt length {plen} exceeds engine "
                    f"max_len={self.max_len}; rejected")
                self.telemetry["rejected"] += 1
                continue
            fit = self.max_len - plen + 1
            if r.max_new_tokens > fit:
                warnings.warn(
                    f"request {r.rid}: max_new_tokens={r.max_new_tokens} "
                    f"does not fit the KV cache after a {plen}-token prompt; "
                    f"truncated to {fit}")
                self.telemetry["truncated"] += 1
                r = dataclasses.replace(r, max_new_tokens=fit)
            admitted.append(r)
        return admitted

    def _record_telemetry(self, reqs: List[Request], results: List[Result]):
        """Fold one batch into the running totals — through the aggregation
        facade when configured (each data-axis shard contributes its share of
        the batch, exactly like gradient shards), host-side otherwise."""
        n_req = len(reqs)
        n_tok = sum(len(r.tokens) for r in results)
        if self.telemetry_channel is not None:
            n_req, n_tok = self.telemetry_channel.reduce(
                [(1.0, len(res.tokens)) for res in results])
        self.telemetry["requests"] += n_req
        self.telemetry["tokens_generated"] += n_tok
        self.telemetry["batches"] += 1

    def _run_batch(self, reqs: List[Request]) -> List[Result]:
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for j, r in enumerate(reqs):
            toks[j, plen - len(r.prompt):] = r.prompt  # left-pad
        cache = self.model.init_cache(b, self.max_len)
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch, cache)
        new = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        # every slot's cache region starts at the BATCH prompt length
        # (left-padding): slot j can hold at most max_len - plen + 1 tokens
        # however generous its own admission-time budget was. That packing
        # shrinkage broke an admission-time promise silently — count it.
        effs = [min(r.max_new_tokens, self.max_len - plen + 1) for r in reqs]
        self.telemetry["truncated_by_packing"] += sum(
            1 for r, e in zip(reqs, effs) if e < r.max_new_tokens)
        # the retirement schedule is static (greedy budgets are known up
        # front): slot j needs effs[j] tokens total, so after step t every
        # slot with effs[j] <= t is done and is sliced OUT of the lockstep
        # batch — decode width shrinks instead of burning max(effs) steps on
        # every slot. Bitwise safe: decode rows are independent (pinned by
        # tests/test_serve.py::test_static_engine_retirement_row_identity).
        live = list(range(b))                    # original slot indices
        steps = [(list(live), new)]              # (live slots, (len,1) toks)
        t = 1                                    # tokens generated per slot
        while t < max(effs):
            keep = [i for i, j in enumerate(live) if effs[j] > t]
            if len(keep) < len(live):
                idx = np.asarray(keep, np.intp)
                live = [live[i] for i in keep]
                new = new[idx]
                cache = jax.tree.map(
                    lambda a: a if getattr(a, "ndim", 0) == 0 else a[:, idx],
                    cache)
            logits, cache = self._decode(self.params, new, cache)
            new = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            steps.append((list(live), new))
            self.telemetry["slot_steps"] += len(live)
            t += 1
        self.telemetry["decode_steps"] += t - 1
        rows: List[List[np.ndarray]] = [[] for _ in range(b)]
        for live_j, col in steps:
            col_np = np.asarray(col)
            for i, j in enumerate(live_j):
                if len(rows[j]) < effs[j]:
                    rows[j].append(col_np[i, 0])
        results = [
            Result(rid=r.rid, tokens=np.asarray(rows[j], np.int32))
            for j, r in enumerate(reqs)
        ]
        self._record_telemetry(reqs, results)
        return results
