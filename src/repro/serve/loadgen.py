"""Poisson load generator + SLO percentile reporting for the serving path.

Models the ROADMAP's "millions of users" traffic shape at benchmark scale:
request arrivals are a Poisson process (exponential inter-arrival times at
``rate`` requests per scheduler step), prompt lengths are drawn from a
discrete mixed distribution (short chat turns + long documents), and decode
budgets from a separate mixed distribution — the regime where static
batching wastes the most work (a lockstep batch runs to its longest slot)
and dense KV allocation pins the most idle memory.

Prompt lengths are drawn from a DISCRETE set on purpose: the continuous
engine prefills unpadded and packs only identical lengths together, so a
small length alphabet keeps the jit cache small while still exercising
mixed-length traffic. Times are in scheduler-step units (1 = one decode
iteration), matching ``ContinuousEngine.run_trace``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.engine import Request

__all__ = ["PoissonLoadGen", "percentile", "latency_report"]


@dataclasses.dataclass
class PoissonLoadGen:
    """Poisson arrivals with mixed prompt/decode length distributions.

    rate: mean arrivals per scheduler step (lambda).
    prompt_lens / prompt_weights: discrete prompt-length distribution.
    max_new / max_new_weights: discrete decode-budget distribution.
    """
    rate: float = 0.5
    prompt_lens: Sequence[int] = (8, 16, 32)
    prompt_weights: Optional[Sequence[float]] = None
    max_new: Sequence[int] = (4, 8, 16, 32, 64)
    max_new_weights: Optional[Sequence[float]] = None
    vocab_size: int = 256
    seed: int = 0

    def trace(self, n: int,
              rng: Optional[np.random.Generator] = None,
              ) -> List[Tuple[float, Request]]:
        """Generate ``n`` arrivals as (t_arrival, Request), time-sorted.

        Every stochastic draw comes from ONE explicitly seeded
        ``np.random.Generator`` — pass ``rng`` to thread a caller-owned
        stream (e.g. one Generator shared by a whole benchmark run, as
        ``benchmarks/fig_serve.py`` does, so BENCH_serve.json is
        reproducible across processes); by default a fresh
        ``default_rng(self.seed)`` makes repeated ``trace`` calls
        identical. The RNG-DISCIPLINE lint rule (tools/repro_lint) pins
        the no-global-state half of this contract repo-wide."""
        if rng is None:
            rng = np.random.default_rng(self.seed)
        pw = self._norm(self.prompt_weights, len(self.prompt_lens))
        nw = self._norm(self.max_new_weights, len(self.max_new))
        t = 0.0
        out: List[Tuple[float, Request]] = []
        for rid in range(n):
            t += float(rng.exponential(1.0 / self.rate))
            plen = int(rng.choice(np.asarray(self.prompt_lens), p=pw))
            budget = int(rng.choice(np.asarray(self.max_new), p=nw))
            prompt = rng.integers(0, self.vocab_size, plen).astype(np.int32)
            out.append((t, Request(rid=rid, prompt=prompt,
                                   max_new_tokens=budget)))
        return out

    @staticmethod
    def _norm(w, n):
        if w is None:
            return np.full(n, 1.0 / n)
        w = np.asarray(w, np.float64)
        return w / w.sum()


def percentile(xs: Sequence[float], p: float) -> float:
    """Percentile over finite values (nan-safe); nan when empty."""
    vals = [x for x in xs if not math.isnan(x)]
    if not vals:
        return math.nan
    return float(np.percentile(np.asarray(vals, np.float64), p))


def latency_report(stats, slo_ttft: Optional[float] = None,
                   slo_tpot: Optional[float] = None) -> Dict[str, float]:
    """p50/p99 TTFT + TPOT (scheduler-step units) over finished requests,
    plus SLO attainment fractions when targets are given."""
    ttfts = [s.ttft for s in stats]
    tpots = [s.tpot for s in stats]
    rep = {
        "n": float(len(stats)),
        "ttft_p50": percentile(ttfts, 50), "ttft_p99": percentile(ttfts, 99),
        "tpot_p50": percentile(tpots, 50), "tpot_p99": percentile(tpots, 99),
    }
    if slo_ttft is not None:
        ok = [t for t in ttfts if not math.isnan(t) and t <= slo_ttft]
        rep["ttft_slo_attainment"] = len(ok) / max(len(stats), 1)
    if slo_tpot is not None:
        fin = [t for t in tpots if not math.isnan(t)]
        ok = [t for t in fin if t <= slo_tpot]
        rep["tpot_slo_attainment"] = len(ok) / max(len(fin), 1)
    return rep
