"""Paged KV cache: fixed-size pages from a global pool + per-slot page tables.

The static engine allocates a dense ``(batch, max_len)`` KV region per batch,
so memory scales with the *worst case* even when most slots hold short
requests. Here the KV store is a global pool of ``num_pages`` fixed-size
pages (``page_size`` token positions each, spanning all layers), and each
decode slot owns only the pages that cover its live tokens:

- ``PageAllocator`` is the host-side free list. It hands out page ids,
  refuses double-frees loudly, and tracks ``in_use`` / ``peak_in_use`` so
  benchmarks can report real footprint against the dense baseline.
- ``PagedKVCache`` owns the device pools ``(L, 1 + num_pages, page, K, hd)``
  and the host page-table mirror ``(num_slots, pages_per_slot)``. Page id 0
  is a reserved scratch ("trash") page: empty slots point every table entry
  at it, so the lockstep decode kernel can scatter their (discarded) K/V
  writes somewhere harmless without branching. Page 0 is never allocated and
  never read by a live slot.

Bit-identity contract (DESIGN.md §11): with ``pages_per_slot * page_size ==
max_len``, gathering a slot's pages yields a ``(max_len, K, hd)`` view whose
allocated positions hold exactly the values a dense per-slot cache would
hold, and whose unallocated positions are masked to ``NEG_INF`` before the
softmax — ``exp`` underflows those lanes to exactly ``0.0``, so the decode
attention output is bitwise identical to the dense-cache oracle.
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["PageAllocator", "PagedKVCache", "pages_needed"]


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages required to hold ``n_tokens`` cache positions."""
    return -(-max(n_tokens, 0) // page_size)


class PageAllocator:
    """Host-side free list over page ids ``1..num_pages`` (0 is scratch).

    Invariants (pinned by tests/test_serve.py):
      - a page is never handed out twice while allocated;
      - freeing a page that is not allocated raises (no double-free);
      - ``alloc`` returns ``None`` on exhaustion — callers translate that
        into queue backpressure, never a crash;
      - freed pages are reused (lowest id first, deterministic).
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1:
            raise ValueError(f"need at least one page, got {num_pages}")
        self.num_pages = num_pages
        self.page_size = page_size
        # heap-free determinism: pop() takes from the tail, so keep the list
        # sorted descending -> lowest free id is handed out first
        self._free: List[int] = list(range(num_pages, 0, -1))
        self._allocated: set[int] = set()
        self.peak_in_use = 0

    @property
    def in_use(self) -> int:
        return len(self._allocated)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages, or return None (backpressure) if they are
        not all available — never a partial allocation."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        self.peak_in_use = max(self.peak_in_use, len(self._allocated))
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._allocated:
                raise ValueError(
                    f"double free (or foreign free) of page {p}: not allocated")
            self._allocated.remove(p)
        # keep descending order so reuse stays deterministic lowest-first
        self._free = sorted(set(self._free) | set(pages), reverse=True)


class PagedKVCache:
    """Device KV pools + per-slot page tables for a layer-stacked decoder.

    Pools are ``(num_layers, 1 + num_pages, page_size, kv_heads, head_dim)``
    — one pool slice per scanned layer, sharing ONE page table across layers
    (a page id addresses the same token span in every layer, the vLLM block
    layout). The page table lives host-side as numpy; the jitted decode gets
    a ``(num_slots, pages_per_slot)`` int32 device copy that is re-uploaded
    only when the table actually changed.
    """

    def __init__(self, cfg, num_slots: int, max_len: int, page_size: int,
                 num_pages: Optional[int] = None):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"paged KV serving supports attention-KV families "
                f"(dense/moe/vlm); got family={cfg.family!r}")
        if max_len % page_size:
            raise ValueError(
                f"page_size={page_size} must divide max_len={max_len} so the "
                f"gathered page view lines up with the dense-cache oracle")
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_slot = max_len // page_size
        if num_pages is None:
            num_pages = num_slots * self.pages_per_slot  # dense-equivalent
        self.allocator = PageAllocator(num_pages, page_size)
        dt = jnp.dtype(cfg.activation_dtype)
        shape = (cfg.num_layers, 1 + num_pages, page_size,
                 cfg.num_kv_heads, cfg.resolved_head_dim)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        # host mirror; 0 = scratch page. Shipped to device on change only.
        self.page_table = np.zeros((num_slots, self.pages_per_slot), np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(num_slots)]
        self._dev_table = None  # device copy, invalidated on table writes

    # ------------------------------------------------------------------
    # slot lifecycle
    # ------------------------------------------------------------------

    def slot_pages(self, slot: int) -> List[int]:
        return list(self._slot_pages[slot])

    def grow_slot(self, slot: int, n_tokens: int) -> bool:
        """Ensure ``slot`` owns pages covering positions [0, n_tokens).
        Returns False (backpressure) when the pool cannot supply them."""
        need = pages_needed(n_tokens, self.page_size)
        have = len(self._slot_pages[slot])
        if need > self.pages_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens need {need} pages > "
                f"pages_per_slot={self.pages_per_slot}")
        if need <= have:
            return True
        pages = self.allocator.alloc(need - have)
        if pages is None:
            return False
        self.page_table[slot, have:need] = pages
        self._slot_pages[slot].extend(pages)
        self._dev_table = None
        return True

    def release_slot(self, slot: int) -> None:
        """Retire a slot: return its pages to the pool and point its table
        back at the scratch page. The pool rows keep stale values — every
        read masks by slot length, so stale lanes are exp-underflowed away."""
        if self._slot_pages[slot]:
            self.allocator.free(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self.page_table[slot, :] = 0
        self._dev_table = None

    # ------------------------------------------------------------------
    # writes
    # ------------------------------------------------------------------

    def write_prompt(self, slot: int, k_prompt, v_prompt) -> None:
        """Copy a prefilled dense cache region into this slot's pages.

        ``k_prompt``/``v_prompt``: ``(L, s, K, hd)`` — layer-stacked K/V for
        one request's prompt (positions [0, s)). The tail of the last page
        is zero-padded; those positions are overwritten by decode before
        they are ever unmasked."""
        s = k_prompt.shape[1]
        npg = pages_needed(s, self.page_size)
        pages = np.asarray(self._slot_pages[slot][:npg], np.int32)
        if npg == 0:
            return
        pad = npg * self.page_size - s
        if pad:
            padw = [(0, 0), (0, pad), (0, 0), (0, 0)]
            k_prompt = jnp.pad(k_prompt, padw)
            v_prompt = jnp.pad(v_prompt, padw)
        L = k_prompt.shape[0]
        kp = k_prompt.reshape(L, npg, self.page_size, *k_prompt.shape[2:])
        vp = v_prompt.reshape(L, npg, self.page_size, *v_prompt.shape[2:])
        self.k = self.k.at[:, pages].set(kp.astype(self.k.dtype))
        self.v = self.v.at[:, pages].set(vp.astype(self.v.dtype))

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    @property
    def pages_in_use(self) -> int:
        return self.allocator.in_use

    @property
    def peak_pages_in_use(self) -> int:
        return self.allocator.peak_in_use

    @property
    def dense_equivalent_tokens(self) -> int:
        """What the static engine's dense allocation would pin for the same
        slot count: ``num_slots * max_len`` cache positions."""
        return self.num_slots * self.max_len

    def device_table(self):
        """Device copy of the page table, re-uploaded only after a table
        write (grow/release) — steady-state decode reuses the cached copy."""
        if self._dev_table is None:
            self._dev_table = jnp.asarray(self.page_table)
        return self._dev_table
