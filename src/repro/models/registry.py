"""Model registry: one uniform functional interface per architecture family."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable  # (key) -> params
    forward: Callable  # (params, batch) -> (logits, aux)
    loss: Callable  # (params, batch) -> scalar
    prefill: Callable  # (params, batch, cache) -> (logits, cache)
    decode_step: Callable  # (params, tokens, cache) -> (logits, cache)
    init_cache: Callable  # (batch_size, max_len) -> cache
    # (params, tokens (B,1), k_pools, v_pools, page_table (B,MP), lens (B,))
    # -> (logits, k_pools, v_pools); None for families without a paged path
    # (encdec; ssm/hybrid raise inside transformer.decode_step_paged)
    decode_step_paged: Any = None


def build(cfg: ModelConfig) -> Model:
    if cfg.is_encoder_decoder:
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            forward=lambda p, b: encdec.forward(p, b, cfg),
            loss=lambda p, b: encdec.loss_fn(p, b, cfg),
            prefill=lambda p, b, c: encdec.prefill(p, b, c, cfg),
            decode_step=lambda p, t, c: encdec.decode_step(p, t, c, cfg),
            init_cache=lambda bs, ml: encdec.init_cache(cfg, bs, ml),
            decode_step_paged=None,
        )
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_lm(key, cfg),
        forward=lambda p, b: transformer.forward(p, b, cfg),
        loss=lambda p, b: transformer.loss_fn(p, b, cfg),
        prefill=lambda p, b, c: transformer.prefill(p, b, c, cfg),
        decode_step=lambda p, t, c: transformer.decode_step(p, t, c, cfg),
        init_cache=lambda bs, ml: transformer.init_cache(cfg, bs, ml),
        decode_step_paged=(
            None if cfg.family not in ("dense", "moe", "vlm") else
            lambda p, t, kp, vp, pt, ln: transformer.decode_step_paged(
                p, t, kp, vp, pt, ln, cfg)),
    )


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
