"""Attention: flash-style chunked GQA (train/prefill), decode, cross-attn.

The chunked path enumerates only the (q-chunk, kv-chunk) pairs that the mask
allows (causal: lower triangle of chunks), scanning over a *static* pair list
with online-softmax state — so HLO FLOPs equal the true causal FLOPs (no
wasted upper-triangle work) and peak memory is O(B*H*Cq*Ck) per step instead
of O(B*H*S^2). This matters for prefill_32k roofline numbers and is the
standard TPU adaptation of flash attention in pure JAX.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, param

NEG_INF = -1e30


def init_attention(key, cfg, rec, path, cross: bool = False):
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "wq": param(ks[0], (d, h, hd), ("embed", "heads", "head_dim"), dt, rec, path + "/wq"),
        "wk": param(ks[1], (d, k, hd), ("embed", "kv_heads", "head_dim"), dt, rec, path + "/wk"),
        "wv": param(ks[2], (d, k, hd), ("embed", "kv_heads", "head_dim"), dt, rec, path + "/wv"),
        "wo": param(ks[3], (h, hd, d), ("heads", "head_dim", "embed"), dt, rec, path + "/wo",
                    scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = param(ks[4], (h, hd), ("heads", "head_dim"), dt, rec, path + "/bq", scale=0.0)
        p["bk"] = param(ks[4], (k, hd), ("kv_heads", "head_dim"), dt, rec, path + "/bk", scale=0.0)
        p["bv"] = param(ks[4], (k, hd), ("kv_heads", "head_dim"), dt, rec, path + "/bv", scale=0.0)
    return p


def _qkv(p, x, cfg, positions=None, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if rope and positions is not None:
        from repro.models.layers import rope_angles

        cos, sin = rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _pair_list(nq: int, nk: int, causal: bool):
    if causal:
        assert nq == nk
        pairs = [(i, j) for i in range(nq) for j in range(i + 1)]
    else:
        pairs = [(i, j) for i in range(nq) for j in range(nk)]
    return jnp.asarray(pairs, jnp.int32)


def chunked_attention(q, k, v, *, causal: bool, q_chunk: int, num_kv_heads: int,
                      remat_step: bool = True):
    """q: (B,S,H,hd); k,v: (B,Sk,K,hd). Returns (B,S,H,hd)."""
    b, s, h, hd = q.shape
    sk = k.shape[1]
    kvh = num_kv_heads
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)

    cq = min(q_chunk, s)
    ck = min(q_chunk, sk)
    # fall back to exact divisibility (shapes here are powers of two)
    while s % cq:
        cq //= 2
    while sk % ck:
        ck //= 2
    nq, nk = s // cq, sk // ck

    if nq == 1 and nk == 1:
        qf = q.reshape(b, s, kvh, g, hd)
        scores = jnp.einsum("bqkgh,bckh->bkgqc", qf, k).astype(jnp.float32) * scale
        if causal:
            mask = jnp.tril(jnp.ones((s, sk), bool))
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqc,bckh->bqkgh", w.astype(v.dtype), v)
        return out.reshape(b, s, h, hd)

    pairs = _pair_list(nq, nk, causal)

    qc = q.reshape(b, nq, cq, kvh, g, hd)
    kc = k.reshape(b, nk, ck, kvh, hd)
    vc = v.reshape(b, nk, ck, kvh, hd)

    o0 = jnp.zeros((nq, b, cq, kvh, g, hd), jnp.float32)
    m0 = jnp.full((nq, b, cq, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, cq, kvh, g), jnp.float32)

    def step(state, pair):
        o, m, l = state
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qc, i, axis=1, keepdims=False)  # (b,cq,K,g,hd)
        kj = jax.lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)  # (b,ck,K,hd)
        vj = jax.lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)
        scores = jnp.einsum("bqkgh,bckh->bqkgc", qi, kj).astype(jnp.float32) * scale
        if causal:
            # global-position causal mask, loop-variant through (i, j) so XLA
            # fuses it into the scores computation instead of hoisting a
            # materialized mask out of the scan (off-diagonal pairs are
            # all-true and fold away)
            rows = i * cq + jnp.arange(cq)
            cols = j * ck + jnp.arange(ck)
            keep = rows[:, None] >= cols[None, :]
            scores = jnp.where(keep[None, :, None, None, :], scores, NEG_INF)
        mi = jax.lax.dynamic_index_in_dim(m, i, axis=0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, axis=0, keepdims=False)
        oi = jax.lax.dynamic_index_in_dim(o, i, axis=0, keepdims=False)
        m_new = jnp.maximum(mi, scores.max(axis=-1).transpose(0, 1, 2, 3))
        # scores: (b,cq,K,g,ck); m/l/o rows are (b,cq,K,g[,hd])
        p_ = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + p_.sum(axis=-1)
        o_new = oi * corr[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p_.astype(vj.dtype), vj
        ).astype(jnp.float32)
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, i, axis=0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, axis=0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, axis=0)
        return (o, m, l), None

    # remat each pair step: backward recomputes the (cq, ck) score tile
    # instead of saving a stacked (n_pairs, B, cq, ck) f32 score tensor per
    # layer — the dominant HBM-traffic term in train/prefill cells. Disabled
    # for hdim-TP archs (cfg.flash_remat=False): their scores carry an
    # all-reduce that recompute would re-run.
    step_fn = jax.checkpoint(step) if remat_step else step
    (o, m, l), _ = jax.lax.scan(step_fn, (o0, m0, l0), pairs)
    out = o / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def _repeat_kv(k, v, cfg):
    """Materialize GQA KV to the full head count for train/prefill einsums.

    Keeps SPMD sharding propagation trivial (q and k/v share the same H axis
    layout) at the cost of a transient g-times larger KV activation — the
    standard Megatron-style duplication; decode keeps the grouped form."""
    g = cfg.num_heads // cfg.num_kv_heads
    if g == 1:
        return k, v
    return jnp.repeat(k, g, axis=2), jnp.repeat(v, g, axis=2)


def attention_train(p, x, cfg, positions, causal: bool = True, rope: bool = True):
    q, k, v = _qkv(p, x, cfg, positions, rope=rope)
    k, v = _repeat_kv(k, v, cfg)
    out = chunked_attention(
        q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk, num_kv_heads=cfg.num_heads,
        remat_step=cfg.flash_remat,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


class KVCache(NamedTuple):
    k: jax.Array  # (B, Smax, K, hd)
    v: jax.Array


def init_kv_cache(batch, max_len, cfg, dtype):
    shape = (batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def attention_prefill(p, x, cfg, positions, cache: KVCache):
    """Run full-sequence attention and write k/v into the cache at [0, S)."""
    q, k, v = _qkv(p, x, cfg, positions)
    cache = KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), 0, axis=1),
        v=jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), 0, axis=1),
    )
    k, v = _repeat_kv(k, v, cfg)
    out = chunked_attention(
        q, k, v, causal=True, q_chunk=cfg.attn_q_chunk, num_kv_heads=cfg.num_heads,
        remat_step=cfg.flash_remat,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


def attention_decode(p, x, cfg, cache: KVCache, pos):
    """x: (B, 1, d); pos: scalar int32 — index of the new token. Attends over
    cache[0..pos]. Returns (out (B,1,d), updated cache)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    kvh = cfg.num_kv_heads
    g = cfg.num_heads // kvh
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)
    cache = KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), pos, axis=1),
        v=jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), pos, axis=1),
    )
    qf = q.reshape(b, kvh, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qf, cache.k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    valid = jnp.arange(cache.k.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(cache.v.dtype), cache.v)
    out = out.reshape(b, 1, cfg.num_heads, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


def attention_decode_paged(p, x, cfg, k_pool, v_pool, page_table, lens):
    """Paged-cache decode step for ONE layer.

    x: (B, 1, d); k_pool/v_pool: (NP, page, K, hd) — this layer's slice of the
    global page pool (page id 0 is reserved scratch); page_table: (B, MP)
    int32 page ids per slot; lens: (B,) int32 tokens already cached per slot
    (the position the new token is written at).

    Per-slot generalization of :func:`attention_decode`: slot j writes its
    new K/V at logical position ``lens[j]`` — physically page
    ``page_table[j, lens[j] // page]`` offset ``lens[j] % page`` — then
    attends over the gathered ``(MP * page,)`` view of its own pages, masked
    at ``<= lens[j]``. With ``MP * page == max_len`` this is bit-identical to
    the dense-cache decode: gathered allocated positions hold the same
    values a dense cache would, and masked lanes exp-underflow to exactly
    0.0 regardless of the (stale/foreign) garbage they hold. Distinct live
    slots own disjoint pages (allocator invariant), so the scatter below has
    no cross-slot index collisions; idle slots all target scratch page 0,
    which no live slot ever reads.

    Returns (out (B, 1, d), new k_pool, new v_pool).
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    kvh = cfg.num_kv_heads
    g = cfg.num_heads // kvh
    page = k_pool.shape[1]
    positions = lens[:, None].astype(jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)
    pidx = jnp.take_along_axis(page_table, (lens // page)[:, None], axis=1)[:, 0]
    off = lens % page
    k_pool = k_pool.at[pidx, off].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[pidx, off].set(v[:, 0].astype(v_pool.dtype))
    kg = k_pool[page_table].reshape(b, -1, kvh, hd)  # (B, MP*page, K, hd)
    vg = v_pool[page_table].reshape(b, -1, kvh, hd)
    qf = q.reshape(b, kvh, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qf, kg).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    valid = jnp.arange(kg.shape[1])[None, :] <= lens[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(vg.dtype), vg)
    out = out.reshape(b, 1, cfg.num_heads, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), k_pool, v_pool


# --- cross attention (whisper decoder) ---


def init_cross_attention(key, cfg, rec, path):
    return init_attention(key, cfg, rec, path)


def cross_attention(p, x, enc_kv, cfg):
    """x: (B,S,d) decoder states; enc_kv: (k,v) each (B,F,K,hd) precomputed."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = _repeat_kv(enc_kv[0], enc_kv[1], cfg)
    out = chunked_attention(
        q, k, v, causal=False, q_chunk=cfg.attn_q_chunk, num_kv_heads=cfg.num_heads,
        remat_step=cfg.flash_remat,
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode_cross_kv(p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return (k, v)
