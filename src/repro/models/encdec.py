"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, F, d_model) directly to the encoder. The
encoder is bidirectional self-attention; the decoder is causal self-attention
+ cross-attention over the encoder output. Learned (sinusoid-free) position
embeddings; cross K/V are computed once at prefill and cached.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.sharding import hints
from repro.models.layers import (
    AxesRecorder,
    apply_mlp,
    embed,
    init_embedding,
    init_lm_head,
    init_mlp,
    init_rms_norm,
    param,
    rms_norm,
)

_REC = AxesRecorder()


def _remat(f, cfg):
    if cfg.remat == "none":
        return f
    return jax.checkpoint(f)


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_rms_norm(cfg.d_model, jnp.dtype(cfg.param_dtype), _REC, "ln1"),
        "attn": attn.init_attention(ks[0], cfg, _REC, "attn"),
        "ln2": init_rms_norm(cfg.d_model, jnp.dtype(cfg.param_dtype), _REC, "ln2"),
        "mlp": init_mlp(ks[1], cfg, _REC, "mlp"),
    }


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_rms_norm(cfg.d_model, jnp.dtype(cfg.param_dtype), _REC, "ln1"),
        "attn": attn.init_attention(ks[0], cfg, _REC, "attn"),
        "lnx": init_rms_norm(cfg.d_model, jnp.dtype(cfg.param_dtype), _REC, "lnx"),
        "xattn": attn.init_cross_attention(ks[1], cfg, _REC, "xattn"),
        "ln2": init_rms_norm(cfg.d_model, jnp.dtype(cfg.param_dtype), _REC, "ln2"),
        "mlp": init_mlp(ks[2], cfg, _REC, "mlp"),
    }


def init_encdec(key, cfg):
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.num_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "embed": init_embedding(ks[2], cfg, _REC, "embed"),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "enc_norm": init_rms_norm(cfg.d_model, dt, _REC, "enc_norm"),
        "final_norm": init_rms_norm(cfg.d_model, dt, _REC, "final_norm"),
        "head": init_lm_head(ks[3], cfg, _REC, "head"),
        # frontend adapter for the stubbed conv features
        "frame_proj": {
            "w": param(ks[4], (cfg.d_model, cfg.d_model), ("embed", "embed2"), dt, _REC, "fp/w")
        },
    }


def encode(params, frames, cfg):
    """frames: (B, F, d_model) stub embeddings -> encoder states (B, F, d)."""
    x = jnp.einsum("bfd,de->bfe", frames.astype(jnp.dtype(cfg.activation_dtype)),
                   params["frame_proj"]["w"])
    x = hints.constrain(x, "batch", None, None)
    b, f, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))

    def body(carry, lp):
        h = attn.attention_train(
            lp["attn"], rms_norm(carry, lp["ln1"]["w"], cfg.norm_eps), cfg, positions,
            causal=False,
        )
        y = carry + h
        z = rms_norm(y, lp["ln2"]["w"], cfg.norm_eps)
        return y + apply_mlp(lp["mlp"], z, cfg), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"]["w"], cfg.norm_eps)


def _dec_block(lp, x, cfg, positions, enc_kv):
    h = attn.attention_train(lp["attn"], rms_norm(x, lp["ln1"]["w"], cfg.norm_eps), cfg, positions)
    x = x + h
    h = attn.cross_attention(lp["xattn"], rms_norm(x, lp["lnx"]["w"], cfg.norm_eps), enc_kv, cfg)
    x = x + h
    z = rms_norm(x, lp["ln2"]["w"], cfg.norm_eps)
    return x + apply_mlp(lp["mlp"], z, cfg)


def forward(params, batch, cfg):
    """batch: {'frames': (B,F,d), 'tokens': (B,S)} -> (logits, aux=0)."""
    enc = encode(params, batch["frames"], cfg)
    x = embed(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.activation_dtype))
    x = hints.constrain(x, "batch", None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        kv = attn.encode_cross_kv(lp["xattn"], enc)
        return _dec_block(lp, carry, cfg, positions, kv), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"])
    return logits, jnp.float32(0)


def loss_fn(params, batch, cfg):
    logits, _ = forward(params, batch, cfg)
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = batch["tokens"][:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


class EncDecCache(NamedTuple):
    self_kv: Any  # KVCache stacked (L, B, Smax, K, hd)
    cross_kv: Any  # (k, v) each (L, B, F, K, hd)
    pos: jax.Array


def init_cache(cfg, batch: int, max_len: int):
    dt = jnp.dtype(cfg.activation_dtype)
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim)
    xshape = (cfg.num_layers, batch, cfg.num_frames, cfg.num_kv_heads, cfg.resolved_head_dim)
    return EncDecCache(
        self_kv=attn.KVCache(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt)),
        cross_kv=(jnp.zeros(xshape, dt), jnp.zeros(xshape, dt)),
        pos=jnp.int32(0),
    )


def prefill(params, batch, cache: EncDecCache, cfg):
    """Encode frames, compute per-layer cross K/V, prefill decoder self-cache."""
    enc = encode(params, batch["frames"], cfg)
    x = embed(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.activation_dtype))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, xs):
        lp, k_l, v_l = xs
        xk, xv = attn.encode_cross_kv(lp["xattn"], enc)
        h, new_c = attn.attention_prefill(
            lp["attn"], rms_norm(carry, lp["ln1"]["w"], cfg.norm_eps), cfg, positions,
            attn.KVCache(k_l, v_l),
        )
        y = carry + h
        h = attn.cross_attention(lp["xattn"], rms_norm(y, lp["lnx"]["w"], cfg.norm_eps),
                                 (xk, xv), cfg)
        y = y + h
        z = rms_norm(y, lp["ln2"]["w"], cfg.norm_eps)
        y = y + apply_mlp(lp["mlp"], z, cfg)
        return y, (new_c.k, new_c.v, xk.astype(k_l.dtype), xv.astype(k_l.dtype))

    x, (ks, vs, xks, xvs) = jax.lax.scan(
        _remat(body, cfg), x, (params["dec_layers"], cache.self_kv.k, cache.self_kv.v)
    )
    x = rms_norm(x[:, -1:, :], params["final_norm"]["w"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"])
    return logits, EncDecCache(
        self_kv=attn.KVCache(ks, vs), cross_kv=(xks, xvs), pos=jnp.int32(s)
    )


def decode_step(params, tokens, cache: EncDecCache, cfg):
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.activation_dtype))
    pos = cache.pos

    def body(carry, xs):
        lp, k_l, v_l, xk, xv = xs
        h, new_c = attn.attention_decode(
            lp["attn"], rms_norm(carry, lp["ln1"]["w"], cfg.norm_eps), cfg,
            attn.KVCache(k_l, v_l), pos,
        )
        y = carry + h
        h = attn.cross_attention(lp["xattn"], rms_norm(y, lp["lnx"]["w"], cfg.norm_eps),
                                 (xk, xv), cfg)
        y = y + h
        z = rms_norm(y, lp["ln2"]["w"], cfg.norm_eps)
        y = y + apply_mlp(lp["mlp"], z, cfg)
        return y, (new_c.k, new_c.v)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache.self_kv.k, cache.self_kv.v,
                  cache.cross_kv[0], cache.cross_kv[1])
    )
    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"])
    return logits, EncDecCache(
        self_kv=attn.KVCache(ks, vs), cross_kv=cache.cross_kv, pos=pos + 1
    )
