"""Mamba2 (SSD — state-space duality) block: chunked train scan + recurrent decode.

Follows the discrete SSD formulation (Dao & Gu, 2024):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * (B_t ⊗ x_t)
    y_t = C_t · h_t + D * x_t
Training uses the chunked block decomposition: exact intra-chunk quadratic
attention-like term + inter-chunk state recurrence (one lax.scan over chunks),
which is sub-quadratic in sequence length — this is why the SSM/hybrid archs
are the ones that run the long_500k shape.

All state math in float32 (dt*A <= 0 so exps are <= 1 and stable).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import param, rms_norm, silu


def init_mamba2(key, cfg, rec, path):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    h = cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    w = cfg.ssm_conv_width
    dt = jnp.dtype(cfg.param_dtype)
    conv_ch = di + 2 * g * n
    ks = jax.random.split(key, 8)
    # in_proj emits [z (di), x (di), B (g*n), C (g*n), dt (h)]
    return {
        "in_proj": param(ks[0], (d, 2 * di + 2 * g * n + h), ("embed", "ssm_proj"), dt, rec, path + "/in_proj"),
        "conv_w": param(ks[1], (w, conv_ch), ("conv_w", "ssm_conv"), dt, rec, path + "/conv_w", scale=0.1),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_w": jnp.ones((di,), dt),
        "out_proj": param(ks[2], (di, d), ("ssm_inner", "embed"), dt, rec, path + "/out_proj",
                          scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _split_proj(cfg, proj):
    di, g, n, h = cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * g * n]
    dt = proj[..., di + di + 2 * g * n :]
    return z, xbc, dt


def _causal_conv_train(xbc, w, b):
    """xbc: (B,S,C); depthwise causal conv, width w.shape[0]."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    s = xbc.shape[1]
    out = sum(pad[:, i : i + s] * w[i] for i in range(width))
    return silu(out + b)


def _segsum(dA):
    """dA: (..., Q) -> (..., Q, Q) with out[q, k] = sum_{i=k+1..q} dA_i (q>=k)."""
    css = jnp.cumsum(dA, axis=-1)
    diff = css[..., :, None] - css[..., None, :]
    q = dA.shape[-1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, bmat, cmat, d_skip, chunk: int):
    """SSD forward.

    x: (B,S,H,P) bf16/f32; dt: (B,S,H) f32 (>0, post-softplus);
    a: (H,) f32 (<0); bmat/cmat: (B,S,G,N); d_skip: (H,).
    Returns y: (B,S,H,P) in x.dtype and final state (B,H,P,N) f32.
    """
    bsz, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hg = h // g
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q

    xf = x.astype(jnp.float32)
    da = dt * a  # (B,S,H), <= 0
    xb = xf * dt[..., None]  # dt-weighted input

    # chunked views
    dac = da.reshape(bsz, nc, q, h)
    xbc = xb.reshape(bsz, nc, q, h, p)
    bc = bmat.reshape(bsz, nc, q, g, n).astype(jnp.float32)
    cc = cmat.reshape(bsz, nc, q, g, n).astype(jnp.float32)

    # ---- intra-chunk (quadratic within chunk) ----
    lmat = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bnqgs,bnkgs->bngqk", cc, bc)  # (B,nc,G,Q,Q)
    scores = jnp.repeat(scores, hg, axis=2)  # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bnhqk,bnkhp->bnqhp", lmat * scores, xbc)

    # ---- chunk-final states ----
    css = jnp.cumsum(dac, axis=2)  # (B,nc,Q,H)
    decay_to_end = jnp.exp(css[:, :, -1:, :] - css)  # (B,nc,Q,H)
    bfull = jnp.repeat(bc, hg, axis=3)  # (B,nc,Q,H,N)
    states = jnp.einsum("bnqhs,bnqh,bnqhp->bnhps", bfull, decay_to_end, xbc)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(css[:, :, -1, :])  # (B,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp  # st: (B,H,P,N); dec: (B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the state *entering* the chunk

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    states_t = states.transpose(1, 0, 2, 3, 4)  # (nc,B,H,P,N)
    decay_t = chunk_decay.transpose(1, 0, 2)  # (nc,B,H)
    final, entering = jax.lax.scan(scan_fn, h0, (states_t, decay_t))
    entering = entering.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(css)  # decay from chunk start to position q
    cfull = jnp.repeat(cc, hg, axis=3)  # (B,nc,Q,H,N)
    y_off = jnp.einsum("bnqhs,bnqh,bnhps->bnqhp", cfull, in_decay, entering)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    y = y + xf * d_skip[None, None, :, None]
    return y.astype(x.dtype), final


def apply_mamba2(p, x, cfg, ssm_state=None, conv_state=None, decode: bool = False):
    """Full mamba2 block. Train/prefill: decode=False, x (B,S,d).
    Decode: x (B,1,d) with (ssm_state (B,H,P,N), conv_state (B,w-1,C)) carried.
    Returns (y, new_ssm_state, new_conv_state)."""
    di, g, n, h = cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim
    width = cfg.ssm_conv_width

    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc_in, dt_raw = _split_proj(cfg, proj)
    a = -jnp.exp(p["a_log"])  # (H,) < 0
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)

    if not decode:
        xbc = _causal_conv_train(xbc_in, p["conv_w"], p["conv_b"])
        new_conv = xbc_in[:, -(width - 1) :, :] if xbc_in.shape[1] >= width - 1 else None
        xs = xbc[..., :di]
        bmat = xbc[..., di : di + g * n].reshape(*xbc.shape[:2], g, n)
        cmat = xbc[..., di + g * n :].reshape(*xbc.shape[:2], g, n)
        xh = xs.reshape(*xs.shape[:2], h, pdim)
        y, final_state = ssd_chunked(xh, dt, a, bmat, cmat, p["d_skip"], cfg.ssm_chunk)
        y = y.reshape(*y.shape[:2], di)
    else:
        # one-step recurrence
        cs = jnp.concatenate([conv_state, xbc_in], axis=1)  # (B, w, C)
        xbc = silu(jnp.einsum("bwc,wc->bc", cs, p["conv_w"]) + p["conv_b"])[:, None, :]
        new_conv = cs[:, 1:, :]
        xs = xbc[..., :di]
        bmat = xbc[..., di : di + g * n].reshape(xbc.shape[0], 1, g, n).astype(jnp.float32)
        cmat = xbc[..., di + g * n :].reshape(xbc.shape[0], 1, g, n).astype(jnp.float32)
        xh = xs.reshape(xs.shape[0], h, pdim).astype(jnp.float32)
        dt1 = dt[:, 0]  # (B,H)
        da = jnp.exp(dt1 * a)  # (B,H)
        hg = h // g
        bfull = jnp.repeat(bmat[:, 0], hg, axis=1)  # (B,H,N)
        cfull = jnp.repeat(cmat[:, 0], hg, axis=1)
        upd = jnp.einsum("bh,bhp,bhs->bhps", dt1, xh, bfull)
        final_state = ssm_state * da[:, :, None, None] + upd
        yh = jnp.einsum("bhs,bhps->bhp", cfull, final_state) + xh * p["d_skip"][None, :, None]
        y = yh.reshape(yh.shape[0], 1, di).astype(x.dtype)

    # gated RMSNorm then output projection
    y = rms_norm(y * silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, final_state, new_conv


def init_ssm_state(batch, cfg):
    return (
        jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        jnp.zeros(
            (batch, cfg.ssm_conv_width - 1, cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state),
            jnp.dtype(cfg.activation_dtype),
        ),
    )
