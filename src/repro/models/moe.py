"""Top-k Mixture-of-Experts with grouped, capacity-bounded index dispatch.

Dispatch/combine use gathers (take_along_axis) rather than one-hot einsums so
HLO FLOPs stay proportional to *active* expert compute (within the capacity
factor) — important for honest MODEL_FLOPS/HLO_FLOPs roofline ratios. Tokens
are routed within groups of `moe_group_size` so the per-expert capacity
buffer (E, C, d) stays small and SPMD-friendly; experts shard over the
'model' (and optionally 'data') mesh axes (EP).

Arctic-style configs add a parallel dense residual MLP (`moe_dense_ff`).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import param, silu
from repro.sharding import hints


def init_moe(key, cfg, rec, path):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": param(ks[0], (d, e), ("embed", "experts"), jnp.float32, rec, path + "/router"),
        "wi": param(ks[1], (e, d, f), ("experts", "embed", "ff"), dt, rec, path + "/wi"),
        "wg": param(ks[2], (e, d, f), ("experts", "embed", "ff"), dt, rec, path + "/wg"),
        "wo": param(ks[3], (e, f, d), ("experts", "ff", "embed"), dt, rec, path + "/wo",
                    scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }


def _capacity(cfg, group_tokens: int) -> int:
    c = int(math.ceil(group_tokens * cfg.num_experts_per_token * cfg.capacity_factor
                      / cfg.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8 lanes


def apply_moe(p, x, cfg):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_token
    t_total = b * s
    tg = min(cfg.moe_group_size, t_total)
    while t_total % tg:
        tg //= 2
    ng = t_total // tg
    cap = _capacity(cfg, tg)

    xg = x.reshape(ng, tg, d)
    # f32 router accumulation WITHOUT materializing f32 activations (a
    # wholesale astype makes XLA hoist an f32 convert of the remat-saved
    # activation stack out of the backward scan — same issue as rms_norm)
    logits = jnp.einsum(
        "gtd,de->gte", xg, p["router"].astype(xg.dtype),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # (ng, tg, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch Transformer style)
    me = probs.mean(axis=(0, 1))  # (e,)
    ce = jnp.zeros((e,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (ng * tg * k)
    aux = e * jnp.sum(me * ce)

    # position of each (token, slot) within its expert queue, per group —
    # sort-based rank, O(T*K) memory (a (T,E) one-hot cumsum is quadratic-ish:
    # 12.9 TB global for kimi-1T's 1M-token batch; verified in the dry-run)
    flat = eidx.reshape(ng, tg * k)
    tgk = tg * k
    sort_idx = jnp.argsort(flat, axis=1, stable=True)  # (ng, tgk)
    sorted_e = jnp.take_along_axis(flat, sort_idx, axis=1)
    ar = jnp.broadcast_to(jnp.arange(tgk, dtype=jnp.int32), (ng, tgk))
    is_start = jnp.concatenate(
        [jnp.ones((ng, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1
    )
    seg_start = jax.lax.cummax(jnp.where(is_start, ar, 0), axis=1)
    rank_sorted = ar - seg_start  # rank within the expert's sorted run
    pos = jnp.zeros((ng, tgk), jnp.int32).at[
        jnp.broadcast_to(jnp.arange(ng)[:, None], (ng, tgk)).reshape(-1),
        sort_idx.reshape(-1),
    ].set(rank_sorted.reshape(-1))
    keep = pos < cap

    # scatter token indices into the (ng, e, cap) slot table
    tok_ids = jnp.broadcast_to(jnp.arange(tg)[:, None], (tg, k)).reshape(tg * k)
    slot_tok = jnp.full((ng, e, cap), tg, jnp.int32)  # sentinel = tg (dropped)
    g_ids = jnp.broadcast_to(jnp.arange(ng)[:, None], (ng, tg * k))
    slot_tok = slot_tok.at[
        g_ids.reshape(-1),
        flat.reshape(-1),
        jnp.where(keep, pos, cap - 1).reshape(-1),
    ].set(jnp.where(keep, tok_ids[None].repeat(ng, 0), tg).reshape(-1), mode="drop")

    # gather tokens into expert buffers (pad row tg = zeros). Sharding: token
    # groups follow the batch axes, experts ride the EP ('model') axis —
    # without these constraints XLA tends to replicate the dispatch buffers
    # (verified: kimi-1T dry-run peaked at 466 GB/device before, ~8 GB after).
    xg_pad = jnp.concatenate([xg, jnp.zeros((ng, 1, d), xg.dtype)], axis=1)
    xg_pad = hints.constrain(xg_pad, "batch", None, None)
    slot_tok = hints.constrain(slot_tok, "batch", "model", None)
    buf = jnp.take_along_axis(
        xg_pad[:, None, :, :], slot_tok[..., None].astype(jnp.int32), axis=2
    )  # (ng, e, cap, d)
    buf = hints.constrain(buf, "batch", "model", None, None)

    # expert FFN (swiglu)
    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"])
    hg = silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"]))
    eout = jnp.einsum("gecf,efd->gecd", h * hg, p["wo"])  # (ng, e, cap, d)
    eout = hints.constrain(eout, "batch", "model", None, None)

    # combine: gather each (token, slot)'s expert output back
    eflat = eout.reshape(ng, e * cap, d)
    eflat = hints.constrain(eflat, "batch", None, None)
    src = flat * cap + jnp.where(keep, pos, 0)  # (ng, tg*k)
    picked = jnp.take_along_axis(eflat, src[..., None], axis=1)  # (ng, tg*k, d)
    picked = jnp.where(keep[..., None], picked, 0.0)
    picked = picked.reshape(ng, tg, k, d)
    out = jnp.einsum("gtk,gtkd->gtd", gates.astype(picked.dtype), picked)
    return out.reshape(b, s, d), aux


def init_dense_residual(key, cfg, rec, path):
    """Arctic: dense MLP running in parallel with the MoE branch."""
    from repro.models.layers import init_mlp

    return init_mlp(key, cfg, rec, path, d_ff=cfg.moe_dense_ff)
