"""Basic neural-net layers, functional style (init fns return pytrees).

Logical-axis annotations: every parameter is created through `param(...)`
with a tuple of logical axis names; sharding/rules.py maps those to mesh
axes. Weights are stored in ``param_dtype`` (bf16 by default); compute
upcasts where numerically required (norms, softmax, SSD state).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

# Registry of parameter path -> logical axes, filled during init by `param`.
# init functions thread an `Axes` recorder for sharding metadata.


class AxesRecorder:
    def __init__(self):
        self.axes: dict = {}

    def record(self, path: str, logical_axes: Sequence[str]):
        self.axes[path] = tuple(logical_axes)


def param(key, shape, logical_axes, dtype, rec: AxesRecorder, path: str, scale=None):
    rec.record(path, logical_axes)
    if scale is None:
        scale = 0.02
    if scale == 0.0:
        return jnp.zeros(shape, dtype)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, weight, eps):
    """RMSNorm with f32 variance accumulation but NO f32 op applied directly
    to x: any convert(x)->f32 in the layer body makes XLA hoist a float32
    convert of the whole remat-saved activation stack out of the backward
    scan (+72 GB/device on the internlm dry-run, +107 GB on kimi; even an
    einsum with preferred_element_type lowers through convert(x)). Squaring
    first keeps the convert on the loop-LOCAL x*x value, which cannot be
    hoisted. The f32 reduction preserves accumulation accuracy; x*x in the
    compute dtype costs ~2^-9 relative on the variance — negligible."""
    t = x * x
    var = jnp.sum(t, axis=-1, keepdims=True, dtype=jnp.float32) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * weight.astype(x.dtype)


def init_rms_norm(d, dtype, rec, path):
    rec.record(path + "/w", ("embed_norm",))
    return {"w": jnp.ones((d,), dtype)}


def silu(x):
    return x * jax.nn.sigmoid(x)


def init_mlp(key, cfg, rec, path, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {
        "wi": param(ks[0], (d, f), ("embed", "ff"), dt, rec, path + "/wi"),
        "wo": param(ks[1], (f, d), ("ff", "embed"), dt, rec, path + "/wo",
                    scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.mlp == "swiglu":
        p["wg"] = param(ks[2], (d, f), ("embed", "ff"), dt, rec, path + "/wg")
    return p


def apply_mlp(p, x, cfg):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if "wg" in p:
        h = silu(jnp.einsum("bsd,df->bsf", x, p["wg"])) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def rope_angles(positions, head_dim, theta):
    """positions: int32 (...,) -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B?, S, hd//2) or (S, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1f, x2f = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def init_embedding(key, cfg, rec, path):
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "tok": param(key, (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), dt, rec, path + "/tok")
    }


def embed(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p_embed, p_head, x, cfg):
    w = p_embed["tok"].T if cfg.tie_embeddings else p_head["w"]
    return jnp.einsum("bsd,dv->bsv", x, w)


def init_lm_head(key, cfg, rec, path):
    if cfg.tie_embeddings:
        return {}
    dt = jnp.dtype(cfg.param_dtype)
    return {"w": param(key, (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), dt, rec, path + "/w")}
