"""TransformerLM: scan-over-layers decoder covering dense / moe / ssm / hybrid
/ vlm families. Functional: ``init`` builds the parameter pytree (stacked
layer weights for lax.scan), ``forward`` / ``prefill`` / ``decode_step`` are
pure functions.

Layer stacking: per-layer parameters are created under vmap so every leaf has
a leading (L, ...) axis and the layer loop is a single `lax.scan` — keeps HLO
size and compile time flat in depth (95-layer deepseek compiles like 24-layer
qwen) and is what makes the 512-device dry-run tractable.

Hybrid (zamba2): `hybrid_attn_every` mamba layers alternate with ONE shared
full transformer block (weights reused at every application, per-application
KV cache) — the Zamba2 pattern. Remainder mamba layers run after the last
shared-block application. (Zamba2's concat-with-embedding input to the shared
block is simplified to a plain residual input; noted in DESIGN.md.)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2, moe
from repro.sharding import hints
from repro.models.layers import (
    AxesRecorder,
    apply_mlp,
    embed,
    init_embedding,
    init_lm_head,
    init_mlp,
    init_rms_norm,
    param,
    rms_norm,
)

_REC = AxesRecorder()  # logical axes resolved post-hoc by sharding/rules.py


def _remat(f, cfg):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(f, policy=pol)
    return jax.checkpoint(f)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_dense_layer(key, cfg):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": init_rms_norm(cfg.d_model, jnp.dtype(cfg.param_dtype), _REC, "ln1"),
        "attn": attn.init_attention(ks[0], cfg, _REC, "attn"),
        "ln2": init_rms_norm(cfg.d_model, jnp.dtype(cfg.param_dtype), _REC, "ln2"),
    }
    if cfg.family == "moe":
        p["moe"] = moe.init_moe(ks[1], cfg, _REC, "moe")
        if cfg.moe_dense_ff:
            p["dense_mlp"] = init_mlp(ks[2], cfg, _REC, "dense_mlp", d_ff=cfg.moe_dense_ff)
    else:
        p["mlp"] = init_mlp(ks[1], cfg, _REC, "mlp")
    return p


def _init_mamba_layer(key, cfg):
    return {
        "ln1": init_rms_norm(cfg.d_model, jnp.dtype(cfg.param_dtype), _REC, "ln1"),
        "mamba": mamba2.init_mamba2(key, cfg, _REC, "mamba"),
    }


def init_lm(key, cfg):
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {"embed": init_embedding(ks[0], cfg, _REC, "embed")}

    if cfg.family in ("dense", "moe", "vlm"):
        layer_keys = jax.random.split(ks[1], cfg.num_layers)
        params["layers"] = jax.vmap(lambda k: _init_dense_layer(k, cfg))(layer_keys)
    elif cfg.family == "ssm":
        layer_keys = jax.random.split(ks[1], cfg.num_layers)
        params["layers"] = jax.vmap(lambda k: _init_mamba_layer(k, cfg))(layer_keys)
    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        ngroups = cfg.num_layers // every
        grouped = ngroups * every
        layer_keys = jax.random.split(ks[1], cfg.num_layers)
        stacked = jax.vmap(lambda k: _init_mamba_layer(k, cfg))(layer_keys)
        params["layers"] = jax.tree.map(
            lambda x: x[:grouped].reshape(ngroups, every, *x.shape[1:]), stacked
        )
        params["tail_layers"] = jax.tree.map(lambda x: x[grouped:], stacked)
        params["shared"] = _init_dense_layer(ks[2], cfg.with_(family="dense"))
    else:
        raise ValueError(cfg.family)

    params["final_norm"] = init_rms_norm(cfg.d_model, jnp.dtype(cfg.param_dtype), _REC, "fn")
    params["head"] = init_lm_head(ks[3], cfg, _REC, "head")
    if cfg.family == "vlm":
        params["vlm_proj"] = {
            "w": param(ks[4], (cfg.d_model, cfg.d_model), ("embed", "embed2"),
                       jnp.dtype(cfg.param_dtype), _REC, "vlm_proj/w")
        }
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _sp(x, cfg):
    """Sequence parallelism (Megatron SP): between the TP einsum segments the
    residual stream shards its seq axis over 'model', so norms/residuals/
    elementwise ops touch 1/TP of the activation bytes. XLA converts the
    attention-out all-reduce into reduce-scatter + all-gather (same wire)."""
    if not cfg.seq_parallel:
        return x
    return hints.constrain(x, "batch", "model", None)


def _dense_block(lp, x, cfg, positions):
    h = attn.attention_train(lp["attn"], rms_norm(x, lp["ln1"]["w"], cfg.norm_eps), cfg, positions)
    x = _sp(x + h, cfg)
    y = rms_norm(x, lp["ln2"]["w"], cfg.norm_eps)
    aux = jnp.float32(0)
    if "moe" in lp:
        out, aux = moe.apply_moe(lp["moe"], y, cfg)
        if "dense_mlp" in lp:
            out = out + apply_mlp(lp["dense_mlp"], y, cfg)
    else:
        out = apply_mlp(lp["mlp"], y, cfg)
    return _sp(x + out, cfg), aux


def _mamba_block(lp, x, cfg):
    h, _, _ = mamba2.apply_mamba2(lp["mamba"], rms_norm(x, lp["ln1"]["w"], cfg.norm_eps), cfg)
    return x + h


# ---------------------------------------------------------------------------
# forward (train)
# ---------------------------------------------------------------------------


def _input_embeds(params, batch, cfg):
    toks = batch["tokens"]
    x = embed(params["embed"], toks)
    if cfg.family == "vlm":
        patches = jnp.einsum("bpd,de->bpe", batch["patch_embeds"].astype(x.dtype),
                             params["vlm_proj"]["w"])
        x = jnp.concatenate([patches, x], axis=1)
    # anchor the activation layout: batch over replica axes, d_model
    # replicated (TP reshards at the einsums). Without this anchor the
    # vocab-sharded embedding gather can leave the batch axis replicated
    # (kimi dry-run: 107 GB/device saved-activation stacks).
    x = hints.constrain(x, "batch", None, None)
    return x.astype(jnp.dtype(cfg.activation_dtype))


def forward(params, batch, cfg):
    """Returns (logits (B, S, V), aux_loss)."""
    x = _input_embeds(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    aux_total = jnp.float32(0)
    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, lp):
            y, aux = _dense_block(lp, carry, cfg, positions)
            return y, aux

        x, auxes = jax.lax.scan(_remat(body, cfg), x, params["layers"])
        aux_total = auxes.sum()
    elif cfg.family == "ssm":
        def body(carry, lp):
            return _mamba_block(lp, carry, cfg), None

        x, _ = jax.lax.scan(_remat(body, cfg), x, params["layers"])
    elif cfg.family == "hybrid":
        shared = params["shared"]

        def group(carry, glp):
            def inner(c, lp):
                return _mamba_block(lp, c, cfg), None

            y, _ = jax.lax.scan(inner, carry, glp)
            y, _ = _dense_block(shared, y, cfg, positions)
            return y, None

        x, _ = jax.lax.scan(_remat(group, cfg), x, params["layers"])

        def tail(carry, lp):
            return _mamba_block(lp, carry, cfg), None

        x, _ = jax.lax.scan(_remat(tail, cfg), x, params["tail_layers"])

    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]["w"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    logits = hints.constrain(logits, "batch", None, "model")
    return logits, aux_total


def loss_fn(params, batch, cfg):
    logits, aux = forward(params, batch, cfg)
    toks = batch["tokens"]
    if cfg.family == "vlm":
        npatch = batch["patch_embeds"].shape[1]
        logits = logits[:, npatch:, :]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = toks[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean() + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


class LMCache(NamedTuple):
    kv: Any  # dense: KVCache stacked (L, ...); hybrid: (ngroups, ...)
    ssm: Any  # (L, B, H, P, N) or None
    conv: Any
    pos: jax.Array  # scalar int32 — number of tokens already in cache


def init_cache(cfg, batch: int, max_len: int):
    dt = jnp.dtype(cfg.activation_dtype)
    kv = ssm = conv = None
    if cfg.family in ("dense", "moe", "vlm"):
        kv = attn.KVCache(
            k=jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim), dt),
            v=jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim), dt),
        )
    elif cfg.family == "ssm":
        s0, c0 = mamba2.init_ssm_state(batch, cfg)
        ssm = jnp.broadcast_to(s0, (cfg.num_layers, *s0.shape))
        conv = jnp.broadcast_to(c0, (cfg.num_layers, *c0.shape))
    elif cfg.family == "hybrid":
        ng = cfg.num_layers // cfg.hybrid_attn_every
        kv = attn.KVCache(
            k=jnp.zeros((ng, batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim), dt),
            v=jnp.zeros((ng, batch, max_len, cfg.num_kv_heads, cfg.resolved_head_dim), dt),
        )
        s0, c0 = mamba2.init_ssm_state(batch, cfg)
        ssm = jnp.broadcast_to(s0, (cfg.num_layers, *s0.shape))
        conv = jnp.broadcast_to(c0, (cfg.num_layers, *c0.shape))
    return LMCache(kv=kv, ssm=ssm, conv=conv, pos=jnp.int32(0))


def _dense_block_decode(lp, x, cfg, cache_l, pos):
    h, cache_l = attn.attention_decode(
        lp["attn"], rms_norm(x, lp["ln1"]["w"], cfg.norm_eps), cfg, cache_l, pos
    )
    x = x + h
    y = rms_norm(x, lp["ln2"]["w"], cfg.norm_eps)
    if "moe" in lp:
        out, _ = moe.apply_moe(lp["moe"], y, cfg)
        if "dense_mlp" in lp:
            out = out + apply_mlp(lp["dense_mlp"], y, cfg)
    else:
        out = apply_mlp(lp["mlp"], y, cfg)
    return x + out, cache_l


def _mamba_block_decode(lp, x, cfg, ssm_l, conv_l):
    y = rms_norm(x, lp["ln1"]["w"], cfg.norm_eps)
    h, ssm_l, conv_l = mamba2.apply_mamba2(lp["mamba"], y, cfg, ssm_l, conv_l, decode=True)
    return x + h, ssm_l, conv_l


def decode_step(params, tokens, cache: LMCache, cfg):
    """tokens: (B, 1) int32. Returns (logits (B, 1, V), new cache)."""
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.activation_dtype))
    pos = cache.pos

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            lp, k_l, v_l = xs
            y, new_c = _dense_block_decode(lp, carry, cfg, attn.KVCache(k_l, v_l), pos)
            return y, (new_c.k, new_c.v)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache.kv.k, cache.kv.v))
        new_cache = LMCache(kv=attn.KVCache(ks, vs), ssm=None, conv=None, pos=pos + 1)
    elif cfg.family == "ssm":
        def body(carry, xs):
            lp, s_l, c_l = xs
            y, s_l, c_l = _mamba_block_decode(lp, carry, cfg, s_l, c_l)
            return y, (s_l, c_l)

        x, (ss, cs) = jax.lax.scan(body, x, (params["layers"], cache.ssm, cache.conv))
        new_cache = LMCache(kv=None, ssm=ss, conv=cs, pos=pos + 1)
    elif cfg.family == "hybrid":
        shared = params["shared"]
        every = cfg.hybrid_attn_every
        ng = cfg.num_layers // every
        grouped = ng * every
        ssm_g = jax.tree.map(lambda a: a[:grouped].reshape(ng, every, *a.shape[1:]),
                             (cache.ssm, cache.conv))

        def group(carry, xs):
            glp, (s_g, c_g), k_g, v_g = xs

            def inner(c, ys):
                lp, s_l, c_l = ys
                y, s_l, c_l = _mamba_block_decode(lp, c, cfg, s_l, c_l)
                return y, (s_l, c_l)

            y, (s_new, c_new) = jax.lax.scan(inner, carry, (glp, s_g, c_g))
            y, kv_new = _dense_block_decode(shared, y, cfg, attn.KVCache(k_g, v_g), pos)
            return y, (s_new, c_new, kv_new.k, kv_new.v)

        x, (ss, cs, ks, vs) = jax.lax.scan(
            group, x, (params["layers"], ssm_g, cache.kv.k, cache.kv.v)
        )

        def tail(carry, ys):
            lp, s_l, c_l = ys
            y, s_l, c_l = _mamba_block_decode(lp, carry, cfg, s_l, c_l)
            return y, (s_l, c_l)

        x, (ts, tc) = jax.lax.scan(
            tail, x, (params["tail_layers"], cache.ssm[grouped:], cache.conv[grouped:])
        )
        new_ssm = jnp.concatenate([ss.reshape(grouped, *ss.shape[2:]), ts], axis=0)
        new_conv = jnp.concatenate([cs.reshape(grouped, *cs.shape[2:]), tc], axis=0)
        new_cache = LMCache(kv=attn.KVCache(ks, vs), ssm=new_ssm, conv=new_conv, pos=pos + 1)

    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]["w"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return logits, new_cache


def _dense_block_decode_paged(lp, x, cfg, k_pool, v_pool, page_table, lens):
    h, k_pool, v_pool = attn.attention_decode_paged(
        lp["attn"], rms_norm(x, lp["ln1"]["w"], cfg.norm_eps), cfg,
        k_pool, v_pool, page_table, lens,
    )
    x = x + h
    y = rms_norm(x, lp["ln2"]["w"], cfg.norm_eps)
    if "moe" in lp:
        out, _ = moe.apply_moe(lp["moe"], y, cfg)
        if "dense_mlp" in lp:
            out = out + apply_mlp(lp["dense_mlp"], y, cfg)
    else:
        out = apply_mlp(lp["mlp"], y, cfg)
    return x + out, k_pool, v_pool


def decode_step_paged(params, tokens, k_pools, v_pools, page_table, lens, cfg):
    """Per-slot decode through a paged KV pool (continuous-batching serving).

    tokens: (B, 1) int32; k_pools/v_pools: (L, NP, page, K, hd) global page
    pools; page_table: (B, MP) int32; lens: (B,) int32 per-slot cache
    lengths. Unlike :func:`decode_step` (one shared scalar position), every
    slot advances at its OWN position — the shape continuous batching needs,
    where slots hold requests admitted at different times. Only attention-KV
    families page (dense/moe/vlm); ssm/hybrid keep per-slot recurrent state
    that has no sequence axis to page.

    Returns (logits (B, 1, V), new k_pools, new v_pools).
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"decode_step_paged supports dense/moe/vlm families, got "
            f"{cfg.family!r} — use the static engine for ssm/hybrid")
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.activation_dtype))

    def body(carry, xs):
        lp, k_l, v_l = xs
        y, k_l, v_l = _dense_block_decode_paged(
            lp, carry, cfg, k_l, v_l, page_table, lens)
        return y, (k_l, v_l)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], k_pools, v_pools))
    x = rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]["w"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return logits, ks, vs


def prefill(params, batch, cache: LMCache, cfg):
    """Run the full prompt through the model, filling caches.

    Returns (last-position logits (B, 1, V), cache)."""
    x = _input_embeds(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            lp, k_l, v_l = xs
            h, new_c = attn.attention_prefill(
                lp["attn"], rms_norm(carry, lp["ln1"]["w"], cfg.norm_eps), cfg, positions,
                attn.KVCache(k_l, v_l),
            )
            y = carry + h
            z = rms_norm(y, lp["ln2"]["w"], cfg.norm_eps)
            if "moe" in lp:
                out, _ = moe.apply_moe(lp["moe"], z, cfg)
                if "dense_mlp" in lp:
                    out = out + apply_mlp(lp["dense_mlp"], z, cfg)
            else:
                out = apply_mlp(lp["mlp"], z, cfg)
            return y + out, (new_c.k, new_c.v)

        x, (ks, vs) = jax.lax.scan(_remat(body, cfg), x, (params["layers"], cache.kv.k, cache.kv.v))
        new_cache = LMCache(kv=attn.KVCache(ks, vs), ssm=None, conv=None, pos=jnp.int32(s))
    elif cfg.family == "ssm":
        def body(carry, xs):
            lp, _s, _c = xs
            y = rms_norm(carry, lp["ln1"]["w"], cfg.norm_eps)
            h, s_new, c_new = mamba2.apply_mamba2(lp["mamba"], y, cfg)
            return carry + h, (s_new, c_new)

        x, (ss, cs) = jax.lax.scan(_remat(body, cfg), x, (params["layers"], cache.ssm, cache.conv))
        new_cache = LMCache(kv=None, ssm=ss, conv=cs, pos=jnp.int32(s))
    elif cfg.family == "hybrid":
        shared = params["shared"]
        every = cfg.hybrid_attn_every
        ng = cfg.num_layers // every
        grouped = ng * every

        def group(carry, xs):
            glp, k_g, v_g = xs

            def inner(c, lp):
                y = rms_norm(c, lp["ln1"]["w"], cfg.norm_eps)
                h, s_new, c_new = mamba2.apply_mamba2(lp["mamba"], y, cfg)
                return c + h, (s_new, c_new)

            y, (s_g, c_g) = jax.lax.scan(inner, carry, glp)
            h, kv_new = attn.attention_prefill(
                shared["attn"], rms_norm(y, shared["ln1"]["w"], cfg.norm_eps), cfg, positions,
                attn.KVCache(k_g, v_g),
            )
            y = y + h
            z = rms_norm(y, shared["ln2"]["w"], cfg.norm_eps)
            y = y + apply_mlp(shared["mlp"], z, cfg)
            return y, (s_g, c_g, kv_new.k, kv_new.v)

        x, (ss, cs, ks, vs) = jax.lax.scan(
            _remat(group, cfg), x, (params["layers"], cache.kv.k, cache.kv.v)
        )

        def tail(carry, lp):
            y = rms_norm(carry, lp["ln1"]["w"], cfg.norm_eps)
            h, s_new, c_new = mamba2.apply_mamba2(lp["mamba"], y, cfg)
            return carry + h, (s_new, c_new)

        x, (ts, tc) = jax.lax.scan(_remat(tail, cfg), x, params["tail_layers"])
        new_ssm = jnp.concatenate([ss.reshape(grouped, *ss.shape[2:]), ts], axis=0)
        new_conv = jnp.concatenate([cs.reshape(grouped, *cs.shape[2:]), tc], axis=0)
        new_cache = LMCache(kv=attn.KVCache(ks, vs), ssm=new_ssm, conv=new_conv, pos=jnp.int32(s))

    x = rms_norm(x[:, -1:, :], params["final_norm"]["w"], cfg.norm_eps)
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["head"]["w"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return logits, new_cache
