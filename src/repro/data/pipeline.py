"""Deterministic synthetic data pipeline with host-shard addressing.

Every batch is a pure function of ``(seed, step, shard_id)`` — a replacement
host that takes over a failed host's shard regenerates *exactly* the batches
the dead host would have produced (the straggler/failure reassignment story;
see runtime/health.py). Background prefetch overlaps host data generation
with device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticCorpus:
    """Zipf-ish token stream with enough structure for a loss to fall."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seed = seed

    def batch(self, step: int, shard_id: int, batch_size: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard_id])
        )
        v = self.vocab_size
        # mixture: repeated n-gram motifs (learnable) + zipf noise
        base = rng.zipf(1.3, size=(batch_size, seq_len)).astype(np.int64) % v
        motif_len = 8
        motif = rng.integers(0, v, size=(batch_size, motif_len))
        reps = seq_len // (2 * motif_len)
        for b in range(batch_size):
            for r in range(reps):
                at = 2 * r * motif_len
                base[b, at : at + motif_len] = motif[b]
        return base.astype(np.int32)


class ShardedLoader:
    """Yields per-host batches; ``shard_id``/``num_shards`` address the global
    batch slice this host owns."""

    def __init__(self, corpus: SyntheticCorpus, global_batch: int, seq_len: int,
                 shard_id: int = 0, num_shards: int = 1, prefetch: int = 2):
        assert global_batch % num_shards == 0
        self.corpus = corpus
        self.local_batch = global_batch // num_shards
        self.seq_len = seq_len
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.prefetch = prefetch

    @property
    def global_batch(self) -> int:
        return self.local_batch * self.num_shards

    def with_shard(self, new_shard_id: int) -> "ShardedLoader":
        """The same stream addressed at a different shard — the failover
        primitive: a replacement host regenerates the dead host's batches
        bit-for-bit (runtime/controller.py re-derives shard ownership from
        HealthMonitor.reassignments with this every step)."""
        if not 0 <= new_shard_id < self.num_shards:
            raise ValueError(f"shard {new_shard_id} out of range "
                             f"[0, {self.num_shards})")
        return ShardedLoader(self.corpus, self.global_batch, self.seq_len,
                             shard_id=new_shard_id, num_shards=self.num_shards,
                             prefetch=self.prefetch)

    def batch_at(self, step: int) -> dict:
        toks = self.corpus.batch(step, self.shard_id, self.local_batch, self.seq_len)
        return {"tokens": toks}

    def __iter__(self) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            step = 0
            while not stop.is_set():
                q.put(self.batch_at(step))
                step += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def reassign_shard(loader: ShardedLoader, new_shard_id: int) -> ShardedLoader:
    """Deterministic failover: a replacement host resumes the dead host's
    stream bit-for-bit (tested in tests/test_runtime.py and, end to end with
    revival retraction, tests/test_recovery.py)."""
    return loader.with_shard(new_shard_id)
