"""Pure-numpy mirrors of the FPISA primitives (fp32 only).

Why this exists: the ``switch_emu`` all-reduce strategy runs the dataplane
emulator inside a ``jax.pure_callback`` — re-entering jax from concurrent
host callbacks deadlocks the CPU PJRT client (all executor threads are
parked inside the callbacks, so the nested dispatch can never be scheduled).
The callback therefore needs a jax-free execution path. It doubles as an
independent third implementation for the parity tests: jnp reference ==
batched jit dataplane == numpy dataplane, bit-for-bit.

Every function here must stay bit-exact vs its twin in ``repro/core/fpisa.py``
(same two's-complement arithmetic shifts, same >=31 clamp, same wrap-around
int32 adds — numpy int32 ops match XLA's semantics on all of these);
``tests/test_switchsim.py`` pins that.
"""
from __future__ import annotations

import numpy as np

EXP_BITS, MAN_BITS, BIAS = 8, 23, 127
EXP_MASK = (1 << EXP_BITS) - 1
MAN_MASK = (1 << MAN_BITS) - 1
IMPLIED_ONE = 1 << MAN_BITS
HEADROOM = 31 - (MAN_BITS + 1)  # 7


def arshift(x, s):
    s = np.clip(np.asarray(s, np.int32), 0, 31)
    return np.right_shift(np.asarray(x, np.int32), s)  # arithmetic on int32


def lshift(x, s):
    s = np.clip(np.asarray(s, np.int32), 0, 31)
    return np.left_shift(np.asarray(x, np.int32), s)


def _floor_log2_u32(x):
    """floor(log2(x)) for uint32 x > 0; -1 for 0 (binary-search port of
    numerics.clz32)."""
    x = np.asarray(x, np.uint32)
    n = np.zeros(x.shape, np.int32)
    for shift in (16, 8, 4, 2, 1):
        big = (x >> np.uint32(shift)) != 0
        n = np.where(big, n + shift, n)
        x = np.where(big, x >> np.uint32(shift), x)
    return np.where(x != 0, n, -1).astype(np.int32)


def encode(x):
    """float32 -> (exp, man) int32 planes; see fpisa.encode."""
    bits = np.asarray(x, np.float32).view(np.int32)
    sign = (bits >> 31) & 1
    exp = (bits >> MAN_BITS) & EXP_MASK
    man = bits & MAN_MASK
    is_denorm = exp == 0
    is_special = exp == EXP_MASK
    exp = np.where(is_special, EXP_MASK - 1, exp)
    man = np.where(is_special, MAN_MASK, man)
    mag = np.where(is_denorm, 0, man | IMPLIED_ONE).astype(np.int32)
    exp = np.where(is_denorm, 0, exp).astype(np.int32)
    signed = np.where(sign == 1, -mag, mag).astype(np.int32)
    return exp, signed


def renormalize(exp, man):
    """(exp, man) planes -> packed float32; see fpisa.renormalize."""
    e = np.asarray(exp, np.int32)
    m = np.asarray(man, np.int32)
    neg = m < 0
    with np.errstate(over="ignore"):
        mag = np.abs(m).astype(np.uint32)  # INT32_MIN wraps, same as jnp
        k = _floor_log2_u32(mag)
        shift = k - MAN_BITS
        m_shifted = np.where(shift >= 0, arshift(m, shift), lshift(m, -shift))
        mag2 = np.abs(m_shifted).astype(np.uint32)
        carry = (mag2 >> np.uint32(MAN_BITS + 1)) != 0
        m_shifted = np.where(carry, arshift(m_shifted, 1), m_shifted)
        shift = shift + carry.astype(np.int32)

        new_e = e + shift
        man_bits_out = np.abs(m_shifted).astype(np.int32) & MAN_MASK

    zero = m == 0
    underflow = new_e <= 0
    overflow = new_e >= EXP_MASK
    exp_out = np.clip(new_e, 0, EXP_MASK)
    exp_out = np.where(zero | underflow, 0, exp_out)
    exp_out = np.where(overflow, EXP_MASK, exp_out)
    man_out = np.where(zero | underflow | overflow, 0, man_bits_out)
    bits = (neg.astype(np.int32) << 31) | (exp_out << MAN_BITS) | man_out
    bits = np.where(zero, 0, bits)
    return bits.astype(np.int32).view(np.float32)


def _overflowed(a, b, s):
    return ((a ^ s) & (b ^ s)) < 0


def fpisa_add_full(acc_exp, acc_man, in_exp, in_man):
    """Full FPISA add (RSAW); see fpisa.fpisa_add_full. Returns
    (exp, man, overwrite, overflow)."""
    d = in_exp - acc_exp
    with np.errstate(over="ignore"):
        m_le = acc_man + arshift(in_man, -d)
        m_gt = arshift(acc_man, d) + in_man
    le = d <= 0
    shifted_in = np.where(le, arshift(in_man, -d), in_man)
    shifted_acc = np.where(le, acc_man, arshift(acc_man, d))
    new_m = np.where(le, m_le, m_gt)
    new_e = np.where(le, acc_exp, in_exp)
    overflow = _overflowed(shifted_acc, shifted_in, new_m)
    return new_e, new_m, np.zeros_like(overflow), overflow


def fpisa_a_add(acc_exp, acc_man, in_exp, in_man):
    """FPISA-A add; see fpisa.fpisa_a_add. Returns
    (exp, man, overwrite, overflow)."""
    d = in_exp - acc_exp
    with np.errstate(over="ignore"):
        right = acc_man + arshift(in_man, -d)
        left = acc_man + lshift(in_man, d)
    use_right = d <= 0
    use_left = (d > 0) & (d <= HEADROOM)
    use_over = d > HEADROOM
    new_m = np.where(use_right, right, np.where(use_left, left, in_man))
    new_e = np.where(use_over, in_exp, acc_exp)
    shifted_in = np.where(use_right, arshift(in_man, -d), lshift(in_man, d))
    overflow = np.where(use_over, False, _overflowed(acc_man, shifted_in, new_m))
    overwrite = use_over & (acc_man != 0)
    return new_e, new_m, overwrite, overflow
