"""Multi-tenant driving of one shared switch dataplane (DESIGN.md §10).

``dataplane.py`` implements the per-packet tenancy *rules* (quota regions,
weighted takeover lottery, priority preemption, per-job counters); this
module supplies the pieces that live above the switch:

* :func:`run_multitenant` — the shared-fabric driver: J jobs (each its own
  worker set, gradient stream, and streaming window) retransmit into ONE
  dataplane round-synchronously, exactly like ``run_aggregation`` does for a
  single job. Packets are submitted job-major within a round, so with
  ``num_jobs=1`` the driver consumes the seeded RNG identically to
  ``run_aggregation`` and the runs are bit-identical (pinned by
  tests/test_multitenant.py).

  Master-backed re-serve: single-tenant SwitchML recycles a slot only after
  every worker already holds the result two windows back, so a cached result
  is never lost while still owed. Cross-tenant takeover breaks that
  guarantee — a stale completed slot can be recycled while some victim
  worker still lacks the result, and its retransmissions would spin forever.
  The driver therefore keeps the master's copy of every completed chunk and
  re-serves it (with the usual per-worker delivery drop draw) whenever a
  retransmission of a completed chunk comes back unanswered — the ATP-style
  parameter-server fallback. The fallback can NEVER fire with one tenant or
  with disjoint quota partitions, so it consumes no RNG in the parity cases.

* :func:`jain_fairness` — Jain's index over per-job goodput (1.0 = perfectly
  fair) for ``benchmarks/fig_contention.py``.

* the **shared-dataplane registry** — named process-global
  ``NumpyDataplane`` instances so several ``switch_emu`` aggregators (one
  per training job, each inside its own ``jax.pure_callback``) plus query
  streams contend for the same emulated switch. The registry keeps per-job
  monotone chunk bases (SwitchML recycling discipline across calls) and a
  monotone staleness clock so one call's leftover slots age out before the
  next tenant's traffic arrives.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro import trace as _trace
from repro.switchsim.dataplane import (
    DataplaneConfig,
    NumpyDataplane,
    run_aggregation,
)

__all__ = [
    "jain_fairness",
    "run_multitenant",
    "reset_shared_dataplanes",
    "shared_dataplane",
    "shared_emulated_allreduce",
]


def jain_fairness(xs) -> float:
    """Jain's fairness index (sum x)^2 / (n * sum x^2) over per-job rates:
    1.0 when every job gets an equal share, 1/n when one job starves all
    others."""
    xs = np.asarray(xs, np.float64)
    denom = len(xs) * float((xs * xs).sum())
    return float(xs.sum()) ** 2 / denom if denom else 0.0


def run_multitenant(
    switch,
    job_vectors,
    drop_prob: float = 0.0,
    seed: int = 0,
    max_rounds: int = 10_000,
    chunk_base: int = 0,
    now_base: int = 0,
):
    """All-reduce each job's (W_j, N_j) vectors through ONE shared switch.

    ``switch`` is a Batched/Numpy dataplane whose config declares
    ``num_jobs == len(job_vectors)`` tenants; ``job_vectors[j]`` must have
    ``cfg.ports[j]`` rows. Every round, each unfinished job contributes its
    eligible packets (per-job self-clocked window over its own quota) and
    the concatenated job-major batch goes through one ingest; completions
    and master-backed re-serves (module doc) deliver results per worker
    under the same i.i.d. drop model as ``run_aggregation``.

    Returns ``(flats, report)``: the per-job aggregated (N_j,) vectors and a
    report dict with ``rounds`` (total rounds driven), ``done_round`` (first
    round after which each job held all results — its completion time), and
    the switch's ``job_stats``.
    """
    cfg = switch.cfg
    jn = cfg.num_jobs
    assert len(job_vectors) == jn, (len(job_vectors), jn)
    e = cfg.elems_per_packet
    vecs3, out, have, got, nlens = [], [], [], [], []
    for j, v in enumerate(job_vectors):
        v = np.asarray(v)
        w, n = v.shape
        assert w == cfg.ports[j], f"job {j}: {w} rows != port count {cfg.ports[j]}"
        pad = (-n) % e
        vp = np.pad(v, ((0, 0), (0, pad))).astype(np.float32)
        nc = vp.shape[1] // e
        vecs3.append(vp.reshape(w, nc, e))
        out.append(np.zeros((nc, e), np.float32))
        have.append(np.zeros((w, nc), bool))
        got.append(np.zeros(nc, bool))
        nlens.append(n)
    rng = np.random.default_rng(seed)
    done_round: list[int | None] = [None] * jn

    sp = _trace.span("switchsim.run_multitenant", phase="switch",
                     num_jobs=jn, drop_prob=drop_prob)
    rnd = 0
    with sp:
        rnd = _drive_tenant_rounds(
            switch, cfg, vecs3, out, have, got, done_round, rng,
            drop_prob=drop_prob, max_rounds=max_rounds,
            chunk_base=chunk_base, now_base=now_base)
        if sp:
            sp.tag(rounds=rnd)
    switch.last_now = now_base + rnd
    flats = [out[j].reshape(-1)[: nlens[j]] for j in range(jn)]
    report = {
        "rounds": rnd,
        "done_round": done_round,
        "job_stats": getattr(switch, "job_stats", None),
    }
    return flats, report


def _drive_tenant_rounds(switch, cfg, vecs3, out, have, got, done_round, rng,
                         *, drop_prob, max_rounds, chunk_base, now_base):
    """The round loop of ``run_multitenant`` (identical RNG stream; split
    out so the driver's trace span wraps exactly the shared-fabric time)."""
    jn = cfg.num_jobs
    rnd = 0
    for rnd in range(max_rounds):
        if all(h.all() for h in have):
            break
        parts = []
        for j in range(jn):
            if have[j].all():
                continue
            window = cfg.job_window(j)
            elig = ~have[j]
            if elig.shape[1] > window:
                elig[:, window:] &= have[j][:, :-window]
            ws, cs = np.nonzero(elig)  # row-major: worker-major packet order
            keep = rng.random(ws.size) >= drop_prob
            ws, cs = ws[keep], cs[keep]
            if ws.size:
                parts.append((np.full(ws.size, j, np.int32), ws, cs,
                              vecs3[j][ws, cs]))
        if not parts:
            continue
        jbs = np.concatenate([p[0] for p in parts])
        ws = np.concatenate([p[1] for p in parts])
        cs = np.concatenate([p[2] for p in parts])
        payloads = np.concatenate([p[3] for p in parts])
        ready, results, accepted = switch.ingest_batch(
            ws, cs + chunk_base, payloads, jobs=jbs, now=now_base + rnd)
        got_pre = [g.copy() for g in got]  # chunks completed BEFORE this round
        for i in np.nonzero(ready)[0]:
            j, c = int(jbs[i]), int(cs[i])
            out[j][c] = results[i]
            got[j][c] = True
            miss = np.nonzero(~have[j][:, c])[0]
            if miss.size:
                ok = rng.random(miss.size) >= drop_prob
                have[j][miss[ok], c] = True
        # master-backed re-serve (module doc): unanswered retransmissions of
        # chunks the master completed in an EARLIER round. A packet the
        # switch neither answered (ready) nor absorbed (accepted) for such a
        # chunk can only mean the slot was recycled out from under the victim
        # by a cross-tenant takeover, so this consumes no RNG in the parity
        # cases. (Same-round completions are excluded: their delivery draw
        # above already covered every missing worker, dup senders included.)
        for i in np.nonzero(~np.asarray(ready) & ~np.asarray(accepted))[0]:
            j, c = int(jbs[i]), int(cs[i])
            if got_pre[j][c]:
                miss = np.nonzero(~have[j][:, c])[0]
                if miss.size:
                    ok = rng.random(miss.size) >= drop_prob
                    have[j][miss[ok], c] = True
        for j in range(jn):
            if done_round[j] is None and have[j].all():
                done_round[j] = rnd + 1
    if not all(h.all() for h in have):
        raise RuntimeError("multi-tenant aggregation did not complete "
                           "within max_rounds")
    return rnd


# ---------------------------------------------------------------------------
# shared emulated switches (switch_emu tenancy wiring)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _SharedSwitch:
    dp: NumpyDataplane
    chunk_base: list  # per-job monotone chunk offset (SwitchML recycling)
    clock: int  # staleness clock handed to the next call as now_base


_SHARED: dict[str, _SharedSwitch] = {}
_SHARED_LOCK = threading.Lock()


def reset_shared_dataplanes():
    """Drop every named shared dataplane (tests / fresh experiments)."""
    with _SHARED_LOCK:
        _SHARED.clear()


def shared_dataplane(name: str, cfg: DataplaneConfig) -> NumpyDataplane:
    """Get or create the named process-global numpy dataplane. Subsequent
    callers must agree on the config — a mismatch is a wiring bug and fails
    loudly rather than silently aggregating across different topologies."""
    with _SHARED_LOCK:
        entry = _SHARED.get(name)
        if entry is None:
            entry = _SharedSwitch(NumpyDataplane(cfg), [0] * cfg.num_jobs, 0)
            _SHARED[name] = entry
        elif entry.dp.cfg != cfg:
            raise ValueError(
                f"shared dataplane {name!r} already exists with config "
                f"{entry.dp.cfg}; refusing mismatched config {cfg}")
        return entry.dp


def shared_emulated_allreduce(
    name: str,
    vals: np.ndarray,
    *,
    num_jobs: int,
    job: int,
    num_slots: int = 8,
    elems_per_packet: int = 256,
) -> np.ndarray:
    """Aggregate (W, N) ``vals`` as tenant ``job`` of the named shared switch
    (host-side: called from the ``switch_emu`` strategy's pure_callback).

    Every tenant drives the same ``NumpyDataplane`` with a fully shared slot
    pool; per-job chunk bases stay monotone across calls and the staleness
    clock advances past ``stale_after`` between calls, so one tenant's
    leftover completed slots are lottery-claimable by the next.
    """
    vals = np.asarray(vals, np.float32)
    w = vals.shape[0]
    cfg = DataplaneConfig(
        num_workers=w, num_slots=num_slots, elems_per_packet=elems_per_packet,
        fmt_name="fp32", variant="fpisa_a", num_jobs=num_jobs,
        job_workers=(w,) * num_jobs)
    shared_dataplane(name, cfg)  # create-or-validate
    with _SHARED_LOCK:
        entry = _SHARED[name]
        nchunks = -(-vals.shape[1] // elems_per_packet)
        out = run_aggregation(
            entry.dp, vals, job=job,
            chunk_base=entry.chunk_base[job], now_base=entry.clock)
        entry.chunk_base[job] += nchunks
        # advance past stale_after: the call's windows age out before the
        # next tenant's traffic arrives
        entry.clock = entry.dp.last_now + cfg.stale_after + 1
        return out.astype(np.float32)
