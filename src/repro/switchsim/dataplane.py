"""Vectorized multi-pipeline FPISA switch dataplane.

State model
-----------
A dataplane is ``num_pipelines`` independent ingress pipelines, each with
``2 * num_slots`` physical aggregation slots (SwitchML's double pool: a
completed slot keeps re-serving its cached result for a full window before
being recycled). All per-slot state is stacked into arrays over the global
slot axis ``G = num_pipelines * 2 * num_slots``:

* ``exp`` / ``man``  — (G, E) int32 FPISA accumulator planes,
* ``seen``           — (G, W) bool worker bitmap (idempotence),
* ``slot_chunk``     — (G,) owning chunk id (-1 = never claimed),
* ``result`` / ``result_valid`` — cached broadcast payload per completed slot.

Chunk ``c`` is striped across pipelines (``pipeline = c % P``) and lands in
physical slot ``(c // P) % (2 * num_slots)`` of that pipeline — with ``P = 1``
this is exactly the legacy ``core/switch.py`` mapping, which is what the
parity tests pin.

Batched ingest
--------------
``ingest_batch`` applies a batch of B packets with *per-slot sequential
semantics*: packets hitting the same slot are applied in batch order (FPISA
addition is order-dependent), while different slots proceed fully in
parallel. The trick is a rank/round decomposition computed inside the jit:

1. stable-sort packets by global slot id; the within-slot *rank* of each
   packet falls out of the sorted segment offsets;
2. scatter packet indices into a (G, rounds) table — round ``r`` holds at
   most one packet per slot;
3. ``lax.scan`` over rounds: each round is one fully vectorized pass of the
   slot state machine (stale drop / claim+reset / bitmap-gated FPISA add /
   completion + delayed renormalization / cached-result re-serve) over all
   G slots at once.

Packets whose rank exceeds ``rounds`` are reported as *deferred* (untouched);
the ``BatchedDataplane`` wrapper resubmits them in order, so any occupancy is
handled while the common case stays a single dispatch.

Pipeline/throughput model
-------------------------
Per-pipeline recirculation counters model the paper's Tofino limitation: the
``full`` (RSAW shift-any-operand) add variant costs one recirculation per
accepted packet — halving per-pipeline packet rate — while ``fpisa_a``
completes in a single pass (Sec. 4.3, 6.1). ``benchmarks/fig10_goodput.py``
turns these counters plus wall-clock packets/sec into the goodput figure.

Stats: ``packets`` (accepted adds), ``duplicates`` (bitmap hits),
``stale`` (retransmissions for an already-recycled slot — counted separately
from duplicates, unlike the pre-refactor emulator which conflated them),
``overwrite`` / ``overflow`` (element counts from the FPISA adds),
``reclaimed`` (in-flight slots freed by dead-worker reclamation, below), and
``recirculations`` per pipeline.

Worker-failure reclamation
--------------------------
A worker that dies mid-aggregation parks every slot still waiting on its
bitmap bit: completion requires all worker bits, so those slots would never
complete and the pool would leak. ``reclaim_worker`` is the control-plane
recovery op, invoked once a heartbeat timeout declares the worker dead (the
training runtime's ``HealthMonitor``; ``run_aggregation`` models the same
timeout with ``detect_rounds``):

* the worker is removed from the *live set* — completion henceforth requires
  only the live workers' bits, and late packets from the dead worker are
  dropped (counted under ``stale``);
* every **in-flight** slot (claimed, result not yet cached) is reset —
  accumulator planes zeroed, bitmap cleared — and counted in ``reclaimed``.
  Survivors still hold the shadow copies of their un-acked chunks (SwitchML's
  retransmission buffer), so their normal timeout retransmissions *resubmit*
  the reset slots from scratch and the chunk completes as a live-worker-only
  sum. Completed slots keep re-serving their cached full-worker results
  unchanged (those chunks finished before the death was declared).

All three dataplanes (batched jit, legacy per-packet shim, numpy) implement
the identical reclamation semantics; tests/test_recovery.py pins the parity.

Multi-tenancy (DESIGN.md §10)
-----------------------------
The switch is a *shared* in-network accelerator: ``num_jobs`` concurrent
tenants (training jobs, query streams, telemetry) ride one dataplane. Each
tenant j gets

* a **quota** ``job_slots[j]`` of logical slots per pipeline — its chunks
  stripe over a contiguous region of the double pool starting at
  ``2 * job_base(j)``; quotas that tile ``num_slots`` give disjoint
  (contention-free) partitions, while the default (every quota =
  ``num_slots``) fully overlaps the pool;
* a **weight** — when a claim attempt hits a *stale* slot owned by another
  tenant, a deterministic per-(slot, round) weighted lottery names the one
  tenant admitted to take it over this round (weighted admission);
* a **priority** — a higher-priority tenant may *preempt* a stale
  lower-priority **in-flight** window (accumulator discarded, victim's
  ``preempted`` counter bumped; the victim's workers simply resubmit once
  they win the slot back). Completed slots are never preempted: their cached
  results keep re-serving until the slot is recycled via the lottery, so
  preemption can never destroy a result a worker is still owed.

A slot is *stale* once no owner-job packet has touched it (claim, add, or
re-serve) for ``stale_after`` driver rounds — the round clock ``now`` is
supplied by the driver with each ingest, so all three dataplanes age slots
identically. Fresh foreign slots always deny the claim (``admission_denied``).
Counters, the live set, and reclamation are all per-job: ``reclaim_worker
(w, job=j)`` resets only in-flight slots *owned by job j*.

Single-tenant equivalence: with ``num_jobs=1`` every tenancy rule is
vacuous (there is no foreign owner), and with quotas that tile the pool and
no cross-tenant traffic every job sees exactly the single-tenant state
machine on its own slot region — both pinned bit-for-bit by
tests/test_multitenant.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro import trace as _trace
from repro.core import fpisa
# the shared mirror contract — defined once in the package root (see the
# repro.switchsim module doc); re-exported here for legacy callers that
# spell switchsim.dataplane.COUNTERS
from repro.switchsim import COUNTERS, SLOT_STATE_FIELDS

_PACKED_DTYPE = {"fp32": jnp.float32, "fp16": jnp.float16, "bf16": jnp.bfloat16}

_I_PACKETS, _I_DUP, _I_STALE, _I_OVERWRITE, _I_OVERFLOW, _I_RECLAIMED, \
    _I_DENIED, _I_PREEMPTED = range(len(COUNTERS))

# modulus/multipliers of the takeover lottery hash: a prime < 2**16 keeps
# every intermediate below 2**25, so the jnp (int32) and numpy planes compute
# the identical value with no overflow divergence
_LOTTERY_MOD = 65521
_LOTTERY_A, _LOTTERY_B, _LOTTERY_C = 257, 193, 11


@dataclasses.dataclass(frozen=True)
class DataplaneConfig:
    """Static shape/semantics of a batched dataplane (hashable: jit-static)."""

    num_workers: int
    num_slots: int = 8  # logical slots per pipeline (physical = 2x: double pool)
    elems_per_packet: int = 256
    fmt_name: str = "fp32"
    variant: str = "fpisa_a"  # fpisa_a | full
    num_pipelines: int = 1
    # max per-slot packets applied per ingest dispatch; 0 -> 2 * num_workers
    # (the worst case one driver round can produce under the window
    # discipline: W retransmissions of the completed chunk + W first packets
    # of the chunk recycling the slot). Overflow packets are deferred.
    rounds_per_call: int = 0
    # --- multi-tenancy (module doc / DESIGN.md §10) ---
    num_jobs: int = 1
    # per-job quota of logical slots per pipeline; None -> num_slots each
    # (fully shared pool). Quotas summing to num_slots tile the pool into
    # disjoint per-job partitions.
    job_slots: tuple[int, ...] | None = None
    # per-job QoS: priority orders in-flight preemption; weight biases the
    # stale-slot takeover lottery. None -> all equal.
    job_priorities: tuple[int, ...] | None = None
    job_weights: tuple[int, ...] | None = None
    # per-job port count (workers); None -> num_workers each. Job j's worker
    # ids live in [0, job_workers[j]); the rest are born non-live for it.
    job_workers: tuple[int, ...] | None = None
    # driver rounds without an owner-job touch before a slot counts as stale
    # (abandoned) and becomes claimable cross-job
    stale_after: int = 4

    @property
    def fmt(self):
        return fpisa.FORMATS[self.fmt_name]

    def _job_tuple(self, field, default) -> tuple[int, ...]:
        val = field if field is not None else (default,) * self.num_jobs
        assert len(val) == self.num_jobs, (val, self.num_jobs)
        return tuple(int(v) for v in val)

    @property
    def quotas(self) -> tuple[int, ...]:
        q = self._job_tuple(self.job_slots, self.num_slots)
        assert all(1 <= v <= self.num_slots for v in q), q
        return q

    @property
    def priorities(self) -> tuple[int, ...]:
        return self._job_tuple(self.job_priorities, 0)

    @property
    def weights(self) -> tuple[int, ...]:
        w = self._job_tuple(self.job_weights, 1)
        assert all(v >= 1 for v in w), w
        return w

    @property
    def ports(self) -> tuple[int, ...]:
        p = self._job_tuple(self.job_workers, self.num_workers)
        assert all(1 <= v <= self.num_workers for v in p), p
        return p

    @property
    def job_bases(self) -> tuple[int, ...]:
        """Logical-slot origin of each job's quota region (quotas tiling
        num_slots -> disjoint regions; full quotas -> everyone at 0)."""
        q, out, acc = self.quotas, [], 0
        for j in range(self.num_jobs):
            out.append(acc % self.num_slots)
            acc += q[j]
        return tuple(out)

    def job_window(self, job: int = 0) -> int:
        """Per-job streaming-window depth: its quota across all pipelines."""
        return self.quotas[job] * self.num_pipelines

    @property
    def physical_slots_per_pipeline(self) -> int:
        return 2 * self.num_slots

    @property
    def total_slots(self) -> int:
        return self.num_pipelines * self.physical_slots_per_pipeline

    @property
    def window(self) -> int:
        """Streaming-window depth in chunks (self-clocking: a worker may send
        chunk c only once it holds the result of c - window)."""
        return self.num_slots * self.num_pipelines

    @property
    def rounds(self) -> int:
        return self.rounds_per_call or 2 * self.num_workers


class DataplaneState(NamedTuple):
    exp: jax.Array  # (G, E) int32 accumulator exponent plane
    man: jax.Array  # (G, E) int32 accumulator mantissa plane
    seen: jax.Array  # (G, W) bool worker bitmap
    slot_chunk: jax.Array  # (G,) int32 chunk owning the slot; -1 = unclaimed
    result: jax.Array  # (G, E) packed-FP cached broadcast payload
    result_valid: jax.Array  # (G,) bool
    counters: jax.Array  # (J, len(COUNTERS)) int32 per-job counters
    recirc: jax.Array  # (P,) int32 per-pipeline recirculation counter
    live: jax.Array  # (J, W) bool — per-job live worker (port) set
    slot_job: jax.Array  # (G,) int32 owning job; -1 = never claimed
    last_touch: jax.Array  # (G,) int32 round of the last owner-job touch


# import-time mirror check: the jitted state layout IS the shared contract
# (the numpy mirror's attributes are checked the same way in its __init__,
# and tools/repro_lint's mirror-parity rule checks both statically)
assert DataplaneState._fields == SLOT_STATE_FIELDS, (
    DataplaneState._fields, SLOT_STATE_FIELDS)


def init_state(cfg: DataplaneConfig) -> DataplaneState:
    g, e = cfg.total_slots, cfg.elems_per_packet
    ports = np.asarray(cfg.ports)
    return DataplaneState(
        exp=jnp.zeros((g, e), jnp.int32),
        man=jnp.zeros((g, e), jnp.int32),
        seen=jnp.zeros((g, cfg.num_workers), bool),
        slot_chunk=jnp.full((g,), -1, jnp.int32),
        result=jnp.zeros((g, e), _PACKED_DTYPE[cfg.fmt_name]),
        result_valid=jnp.zeros((g,), bool),
        counters=jnp.zeros((cfg.num_jobs, len(COUNTERS)), jnp.int32),
        recirc=jnp.zeros((cfg.num_pipelines,), jnp.int32),
        live=jnp.asarray(np.arange(cfg.num_workers)[None, :] < ports[:, None]),
        slot_job=jnp.full((g,), -1, jnp.int32),
        last_touch=jnp.zeros((g,), jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("cfg",))
def reclaim_dead_worker(state: DataplaneState, worker, job=0, *,
                        cfg: DataplaneConfig) -> DataplaneState:
    """Remove ``worker`` from ``job``'s live set and reset every in-flight
    slot **owned by that job** (module doc: Worker-failure reclamation).
    Other tenants' slots, live sets, and counters are untouched. Idempotent:
    reclaiming an already-dead worker is a no-op."""
    was_live = state.live[job, worker]
    inflight = (was_live & (state.slot_chunk >= 0) & ~state.result_valid
                & (state.slot_job == job))
    return state._replace(
        exp=jnp.where(inflight[:, None], 0, state.exp),
        man=jnp.where(inflight[:, None], 0, state.man),
        seen=jnp.where(inflight[:, None], False, state.seen),
        live=state.live.at[job, worker].set(False),
        counters=state.counters.at[job, _I_RECLAIMED].add(
            jnp.sum(inflight).astype(jnp.int32)),
    )


def slot_of(cfg: DataplaneConfig, chunks):
    """Global slot id for each chunk id (pipeline striping + double pool) —
    the single-tenant mapping, identical to ``slot_of_tenant`` with job 0 and
    a full quota."""
    pipe = chunks % cfg.num_pipelines
    slot = (chunks // cfg.num_pipelines) % cfg.physical_slots_per_pipeline
    return pipe * cfg.physical_slots_per_pipeline + slot


def slot_of_tenant(cfg: DataplaneConfig, jobs, chunks, xp=np):
    """Global slot id under per-job quota striping: job j's chunk stream
    wraps over the ``2 * quotas[j]`` physical slots starting at
    ``2 * job_bases[j]`` of its pipeline. With a full quota (base 0) this is
    exactly ``slot_of`` — the single-tenant parity anchor."""
    phys = cfg.physical_slots_per_pipeline
    q = xp.asarray(cfg.quotas)[jobs]
    base = xp.asarray(cfg.job_bases)[jobs]
    pipe = chunks % cfg.num_pipelines
    idx = (chunks // cfg.num_pipelines) % (2 * q)
    return pipe * phys + (2 * base + idx) % phys


def lottery_pref(cfg: DataplaneConfig, now, xp=np):
    """(G,) preferred tenant per slot for round ``now`` — the weighted
    admission lottery for stale-slot takeovers. A pure function of
    (slot, round, weights): order-free within a round and bit-identical
    across the jnp and numpy dataplanes (int32-safe modular hash)."""
    weights = cfg.weights
    g = xp.arange(cfg.total_slots, dtype=xp.int32)
    h = ((g % _LOTTERY_MOD) * _LOTTERY_A + (now % _LOTTERY_MOD) * _LOTTERY_B
         + _LOTTERY_C) % _LOTTERY_MOD
    cumw = xp.asarray(np.cumsum(weights, dtype=np.int32))
    return xp.searchsorted(cumw, h % sum(weights), side="right").astype(xp.int32)


def _rank_table(key, valid, num_keys: int, rounds: int):
    """Scatter packet indices into a (num_keys, rounds) table such that column
    r holds (at most) the r-th packet, in batch order, of every key.

    Returns (table int32 with -1 for empty cells, deferred bool mask over the
    batch marking packets whose within-key rank >= rounds)."""
    b = key.shape[0]
    key = jnp.where(valid, key, num_keys)  # invalid -> sentinel, dropped below
    order = jnp.argsort(key)  # stable: preserves batch order within a key
    ks = key[order]
    first = jnp.concatenate([jnp.ones((1,), bool), ks[1:] != ks[:-1]])
    seg_start = jnp.where(first, jnp.arange(b), 0)
    seg_start = lax.associative_scan(jnp.maximum, seg_start)
    rank = jnp.arange(b) - seg_start

    fits = (ks < num_keys) & (rank < rounds)
    table = jnp.full((num_keys, rounds), -1, jnp.int32)
    table = table.at[
        jnp.where(fits, ks, num_keys), jnp.where(fits, rank, 0)
    ].set(order.astype(jnp.int32), mode="drop")
    deferred = jnp.zeros((b,), bool).at[order].set((ks < num_keys) & (rank >= rounds))
    return table, deferred


@functools.partial(jax.jit, static_argnames=("cfg", "rounds"))
def ingest_batch(state: DataplaneState, workers, chunks, payloads, valid,
                 jobs=None, now=0, *,
                 cfg: DataplaneConfig, rounds: int | None = None):
    """Apply a batch of packets to the dataplane (see module doc).

    Args:
      state:    DataplaneState.
      workers:  (B,) int32 worker ids in [0, num_workers).
      chunks:   (B,) int32 chunk ids.
      payloads: (B, E) float payloads.
      valid:    (B,) bool lane mask (padding lanes are ignored).
      jobs:     (B,) int32 tenant ids in [0, num_jobs); None -> all job 0.
      now:      scalar driver round (the staleness clock; traced, so driving
                it every round never recompiles).

    Returns ``(state, ready, results, accepted, deferred)`` where ``ready``
    marks packets answered with a broadcast payload (slot completion or
    idempotent re-serve of a completed chunk), ``results`` holds those
    payloads, ``accepted`` marks packets whose contribution was added (first
    arrival of a (worker, chunk)), and ``deferred`` marks packets not
    processed this call (per-slot rank overflow; resubmit in order).
    """
    g, w_n, b = cfg.total_slots, cfg.num_workers, workers.shape[0]
    rounds = rounds or cfg.rounds
    fmt = cfg.fmt
    add = fpisa.fpisa_a_add if cfg.variant == "fpisa_a" else fpisa.fpisa_add_full
    planes = fpisa.encode(payloads, fmt)
    if jobs is None:
        jobs = jnp.zeros((b,), jnp.int32)
    jobs = jnp.clip(jobs, 0, cfg.num_jobs - 1).astype(jnp.int32)

    table, deferred = _rank_table(
        slot_of_tenant(cfg, jobs, chunks, jnp), valid, g, rounds)
    lane_pipe = jnp.arange(g) // cfg.physical_slots_per_pipeline
    prio = jnp.asarray(cfg.priorities)
    pref = lottery_pref(cfg, now, jnp)  # constant across this call's rounds

    ready0 = jnp.zeros((b,), bool)
    results0 = jnp.zeros((b, cfg.elems_per_packet), _PACKED_DTYPE[cfg.fmt_name])
    accepted0 = jnp.zeros((b,), bool)

    def round_body(carry, pidx):
        st, ready, results, accepted = carry
        active = pidx >= 0
        pi = jnp.where(active, pidx, 0)
        wk, ck, jb = workers[pi], chunks[pi], jobs[pi]
        inp = fpisa.Planes(planes.exp[pi], planes.man[pi])

        cur = st.slot_chunk
        owner = st.slot_job
        owner_c = jnp.clip(owner, 0, cfg.num_jobs - 1)
        # packets from reclaimed (dead) workers are dropped like stale ones
        act = active & st.live[jb, wk]
        is_dead = active & ~st.live[jb, wk]
        free = cur < 0
        same = act & (free | (owner == jb))
        cross = act & ~free & (owner != jb)

        # same-tenant path: the classic single-tenant slot machine
        s_stale = same & (cur > ck)
        is_new = same & (cur < ck)  # includes free slots (cur = -1)
        s_dup = same & (cur == ck)

        # cross-tenant path: fresh slots deny; stale slots are claimable by
        # takeover (completed: weighted lottery, or higher priority) or
        # preemption (in-flight: higher priority, or equal priority winning
        # the lottery — keeps abandoned windows from deadlocking the slot)
        slot_stale = (now - st.last_touch) >= cfg.stale_after
        higher = prio[jb] > prio[owner_c]
        equal = prio[jb] == prio[owner_c]
        takeover = cross & st.result_valid & slot_stale & (higher | (pref == jb))
        preempt = (cross & ~st.result_valid & slot_stale
                   & (higher | (equal & (pref == jb))))
        denied = cross & ~(takeover | preempt)

        claim = is_new | takeover | preempt
        is_stale = is_dead | s_stale
        proceed = claim | s_dup

        # claim: reset the slot for the new (job, chunk) ownership
        seen = jnp.where(claim[:, None], False, st.seen)
        exp = jnp.where(claim[:, None], 0, st.exp)
        man = jnp.where(claim[:, None], 0, st.man)
        rvalid = jnp.where(claim, False, st.result_valid)
        slot_chunk = jnp.where(claim, ck, cur)
        slot_job = jnp.where(claim, jb, owner)
        # owner-job activity refreshes the staleness clock (claims, adds, and
        # re-serve dups); denied/stale/dead packets do not
        last_touch = jnp.where(proceed, now, st.last_touch)

        already = seen[jnp.arange(g), jnp.where(proceed, wk, 0)]
        is_dup = proceed & already
        do_add = proceed & ~already

        newp, addst = add(fpisa.Planes(exp, man), inp, fmt)
        exp = jnp.where(do_add[:, None], newp.exp, exp)
        man = jnp.where(do_add[:, None], newp.man, man)
        seen = seen | (do_add[:, None] & (jnp.arange(w_n)[None, :] == wk[:, None]))
        # completion requires every LIVE worker's bit of the packet's own
        # tenant (dead/unported bits are waived)
        complete = do_add & jnp.all(seen | ~st.live[jb], axis=1)

        # delayed renormalization only on rounds that complete a slot
        result, rvalid = lax.cond(
            jnp.any(complete),
            lambda r, rv: (
                jnp.where(complete[:, None],
                          fpisa.renormalize(fpisa.Planes(exp, man), fmt), r),
                rv | complete,
            ),
            lambda r, rv: (r, rv),
            st.result, rvalid,
        )

        serve = complete | (is_dup & rvalid)
        # most rounds serve nothing (completion needs rank == W-1): skip the
        # (G -> B, E) result scatter unless some lane actually answers
        ready, results = lax.cond(
            jnp.any(serve),
            lambda rd, rs: (
                # b = out-of-bounds sentinel: non-serving lanes are dropped
                rd.at[jnp.where(serve, pi, b)].set(True, mode="drop"),
                rs.at[jnp.where(serve, pi, b)].set(result, mode="drop"),
            ),
            lambda rd, rs: (rd, rs),
            ready, results,
        )
        accepted = accepted.at[jnp.where(do_add, pi, b)].set(True, mode="drop")

        # per-job counters: commutative scatter-adds keyed by the packet's
        # tenant (preempted is charged to the VICTIM), so batched/numpy/
        # per-packet stay order-independent and bit-identical
        i32 = lambda m: m.astype(jnp.int32)  # noqa: E731
        counters = st.counters
        counters = counters.at[jb, _I_PACKETS].add(i32(do_add))
        counters = counters.at[jb, _I_DUP].add(i32(is_dup))
        counters = counters.at[jb, _I_STALE].add(i32(is_stale))
        counters = counters.at[jb, _I_OVERWRITE].add(
            jnp.sum(jnp.where(do_add[:, None], addst.overwrite, False),
                    axis=1).astype(jnp.int32))
        counters = counters.at[jb, _I_OVERFLOW].add(
            jnp.sum(jnp.where(do_add[:, None], addst.overflow, False),
                    axis=1).astype(jnp.int32))
        counters = counters.at[jb, _I_DENIED].add(i32(denied))
        counters = counters.at[owner_c, _I_PREEMPTED].add(i32(preempt))
        # RSAW full-add costs one recirculation pass per accepted packet
        recirc = st.recirc
        if cfg.variant == "full":
            recirc = recirc + jax.ops.segment_sum(
                do_add.astype(jnp.int32), lane_pipe, num_segments=cfg.num_pipelines)

        st = DataplaneState(exp, man, seen, slot_chunk, result, rvalid,
                            counters, recirc, st.live, slot_job, last_touch)
        return (st, ready, results, accepted), None

    (state, ready, results, accepted), _ = lax.scan(
        round_body, (state, ready0, results0, accepted0), table.T)
    return state, ready, results, accepted, deferred


def _pow2ceil(n: int, floor: int = 1) -> int:
    p = floor
    while p < n:
        p *= 2
    return p


class BatchedDataplane:
    """Host-side handle: owns the device state, pads/submits numpy batches,
    resubmits deferred packets, and exposes legacy-style ``stats``.

    Jit specialization discipline: batches are padded to one of (at most) two
    fixed sizes and the per-slot round count is the power-of-two cover of the
    batch's actual max slot occupancy, capped at ``cfg.rounds`` — so the
    compile cache stays small and steady-state driving never recompiles."""

    def __init__(self, cfg: DataplaneConfig, max_batch: int | None = None):
        self.cfg = cfg
        self.state = init_state(cfg)
        # largest batch one driver round can produce under the window
        # discipline (every worker's full in-flight window)
        self.max_batch = max_batch or min(
            _pow2ceil(cfg.num_workers * cfg.window), 8192)
        self._sizes = sorted({min(256, self.max_batch), self.max_batch})

    def _pad_size(self, n: int) -> int:
        for s in self._sizes:
            if n <= s:
                return s
        return self.max_batch

    def ingest_batch(self, workers, chunks, payloads, jobs=None, now=0):
        """Process packets (numpy in/out). Returns (ready, results, accepted)
        aligned with the input batch; within-slot application order is the
        batch order, matching a sequential per-packet switch. ``jobs`` tags
        each packet with its tenant (None -> job 0); ``now`` is the driver's
        round clock for staleness aging."""
        workers = np.asarray(workers, np.int32)
        chunks = np.asarray(chunks, np.int32)
        payloads = np.asarray(payloads, np.float32).reshape(
            len(workers), self.cfg.elems_per_packet)
        b = len(workers)
        jobs_np = (np.zeros(b, np.int32) if jobs is None
                   else np.asarray(jobs, np.int32))
        ready = np.zeros(b, bool)
        results = np.zeros((b, self.cfg.elems_per_packet), np.float32)
        accepted = np.zeros(b, bool)
        gids = np.asarray(slot_of_tenant(
            self.cfg, jobs_np.astype(np.int64), chunks.astype(np.int64)))
        queue = np.arange(b)
        while queue.size:
            cur, queue = queue[: self.max_batch], queue[self.max_batch :]
            bp = self._pad_size(cur.size)
            occ = int(np.bincount(gids[cur]).max())
            rounds = min(_pow2ceil(occ), self.cfg.rounds)
            pad = bp - cur.size
            wk = np.pad(workers[cur], (0, pad))
            ck = np.pad(chunks[cur], (0, pad))
            jb = np.pad(jobs_np[cur], (0, pad))
            pl = np.pad(payloads[cur], ((0, pad), (0, 0)))
            vmask = np.arange(bp) < cur.size
            self.state, rdy, res, acc, dfr = ingest_batch(
                self.state, jnp.asarray(wk), jnp.asarray(ck), jnp.asarray(pl),
                jnp.asarray(vmask), jnp.asarray(jb), jnp.int32(now),
                cfg=self.cfg, rounds=rounds)
            rdy = np.asarray(rdy)[: cur.size]
            res = np.asarray(res, np.float32)[: cur.size]
            acc = np.asarray(acc)[: cur.size]
            dfr = np.asarray(dfr)[: cur.size]
            ready[cur[rdy]] = True
            results[cur[rdy]] = res[rdy]
            accepted[cur[acc]] = True
            # deferred packets (rank overflow) go back FIRST: they precede
            # everything not yet submitted in the original batch order
            if dfr.any():
                queue = np.concatenate([cur[dfr], queue])
        return ready, results, accepted

    def reclaim_worker(self, worker: int, job: int = 0):
        """Control-plane recovery: drop ``worker`` from ``job``'s live set and
        reset its parked in-flight slots (module doc). Survivor
        retransmissions resubmit the reset chunks from their shadow copies."""
        self.state = reclaim_dead_worker(
            self.state, jnp.int32(worker), jnp.int32(job), cfg=self.cfg)

    @property
    def stats(self) -> dict:
        """Legacy switch-wide stats: per-job counters summed over tenants."""
        c = np.asarray(self.state.counters).sum(axis=0)
        out = {name: int(c[i]) for i, name in enumerate(COUNTERS)}
        out["recirculations"] = np.asarray(self.state.recirc).tolist()
        return out

    @property
    def job_stats(self) -> list[dict]:
        """Per-tenant counters, one dict per job id."""
        c = np.asarray(self.state.counters)
        return [{name: int(c[j, i]) for i, name in enumerate(COUNTERS)}
                for j in range(self.cfg.num_jobs)]


class NumpyDataplane:
    """Jax-free dataplane with the exact same slot semantics and
    ``ingest_batch`` interface as ``BatchedDataplane`` (per-packet numpy loop
    over ``npfpisa`` primitives — bit-identical, tests pin it).

    Exists for contexts that must not re-enter jax — above all the
    ``switch_emu`` all-reduce strategy, whose host callback would deadlock
    the CPU PJRT client if it dispatched jitted computations (see
    npfpisa module doc). Also a handy pdb-able reference."""

    def __init__(self, cfg: DataplaneConfig):
        from repro.switchsim import npfpisa

        assert cfg.fmt_name == "fp32", "numpy dataplane is fp32-only"
        self.cfg = cfg
        self._np = npfpisa
        g, e = cfg.total_slots, cfg.elems_per_packet
        self._exp = np.zeros((g, e), np.int32)
        self._man = np.zeros((g, e), np.int32)
        self._seen = np.zeros((g, cfg.num_workers), bool)
        self._slot_chunk = np.full((g,), -1, np.int64)
        self._result = np.zeros((g, e), np.float32)
        self._result_valid = np.zeros((g,), bool)
        self._live = (np.arange(cfg.num_workers)[None, :]
                      < np.asarray(cfg.ports)[:, None])
        self._slot_job = np.full((g,), -1, np.int64)
        self._last_touch = np.zeros((g,), np.int64)
        self._counters = np.zeros((cfg.num_jobs, len(COUNTERS)), np.int64)
        self._recirc = [0] * cfg.num_pipelines
        # runtime half of the mirror contract (static half: repro-lint's
        # mirror-parity rule): one `_`-prefixed attribute per shared
        # slot-state field, so the two dataplanes cannot drift silently
        missing = [f for f in SLOT_STATE_FIELDS
                   if not hasattr(self, f"_{f}")]
        assert not missing, f"NumpyDataplane missing mirror fields {missing}"

    @property
    def stats(self) -> dict:
        """Legacy switch-wide stats: per-job counters summed over tenants."""
        c = self._counters.sum(axis=0)
        out = {name: int(c[i]) for i, name in enumerate(COUNTERS)}
        out["recirculations"] = list(self._recirc)
        return out

    @property
    def job_stats(self) -> list[dict]:
        """Per-tenant counters, one dict per job id."""
        return [{name: int(self._counters[j, i])
                 for i, name in enumerate(COUNTERS)}
                for j in range(self.cfg.num_jobs)]

    def reclaim_worker(self, worker: int, job: int = 0):
        """Same reclamation semantics as ``BatchedDataplane.reclaim_worker``:
        only slots owned by ``job`` are reset."""
        if not self._live[job, worker]:
            return
        self._live[job, worker] = False
        inflight = ((self._slot_chunk >= 0) & ~self._result_valid
                    & (self._slot_job == job))
        self._exp[inflight] = 0
        self._man[inflight] = 0
        self._seen[inflight] = False
        self._counters[job, _I_RECLAIMED] += int(inflight.sum())

    def ingest_batch(self, workers, chunks, payloads, jobs=None, now=0):
        cfg, F = self.cfg, self._np
        workers = np.asarray(workers, np.int64)
        chunks = np.asarray(chunks, np.int64)
        payloads = np.asarray(payloads, np.float32).reshape(
            len(workers), cfg.elems_per_packet)
        b = len(workers)
        jobs = (np.zeros(b, np.int64) if jobs is None
                else np.asarray(jobs, np.int64))
        add = F.fpisa_a_add if cfg.variant == "fpisa_a" else F.fpisa_add_full
        gids = np.asarray(slot_of_tenant(cfg, jobs, chunks))
        pref = lottery_pref(cfg, int(now), np)
        prio = cfg.priorities
        in_exp, in_man = F.encode(payloads)
        ready = np.zeros(b, bool)
        results = np.zeros((b, cfg.elems_per_packet), np.float32)
        accepted = np.zeros(b, bool)
        ct = self._counters
        for i in range(b):
            g, w, c, j = int(gids[i]), int(workers[i]), int(chunks[i]), int(jobs[i])
            if not self._live[j, w]:
                ct[j, _I_STALE] += 1
                continue
            cur, owner = self._slot_chunk[g], int(self._slot_job[g])
            if cur >= 0 and owner != j:
                # cross-tenant: deny fresh slots; stale ones fall to the
                # takeover lottery / priority preemption (jit round_body
                # mirrors these rules lane-wise)
                slot_stale = (int(now) - self._last_touch[g]) >= cfg.stale_after
                higher = prio[j] > prio[owner]
                equal = prio[j] == prio[owner]
                if self._result_valid[g]:
                    allowed = slot_stale and (higher or pref[g] == j)
                else:
                    allowed = slot_stale and (higher or (equal and pref[g] == j))
                    if allowed:
                        ct[owner, _I_PREEMPTED] += 1
                if not allowed:
                    ct[j, _I_DENIED] += 1
                    continue
                claim = True
            elif cur > c:
                ct[j, _I_STALE] += 1
                continue
            else:
                claim = cur < c
            if claim:  # reset the slot for the new (job, chunk) ownership
                self._slot_chunk[g] = c
                self._slot_job[g] = j
                self._seen[g] = False
                self._exp[g] = 0
                self._man[g] = 0
                self._result_valid[g] = False
            self._last_touch[g] = int(now)  # owner-job activity: not stale
            if self._seen[g, w]:
                ct[j, _I_DUP] += 1  # idempotent: do NOT re-add
                if self._result_valid[g]:
                    ready[i] = True
                    results[i] = self._result[g]
                continue
            self._seen[g, w] = True
            ct[j, _I_PACKETS] += 1
            e2, m2, over, ovf = add(self._exp[g], self._man[g], in_exp[i], in_man[i])
            self._exp[g], self._man[g] = e2, m2
            ct[j, _I_OVERWRITE] += int(over.sum())
            ct[j, _I_OVERFLOW] += int(ovf.sum())
            accepted[i] = True
            if cfg.variant == "full":
                self._recirc[g // cfg.physical_slots_per_pipeline] += 1
            if (self._seen[g] | ~self._live[j]).all():
                self._result[g] = F.renormalize(self._exp[g], self._man[g])
                self._result_valid[g] = True
                ready[i] = True
                results[i] = self._result[g]
        return ready, results, accepted


def run_aggregation(
    switch,
    worker_vectors: np.ndarray,
    drop_prob: float = 0.0,
    seed: int = 0,
    max_rounds: int = 10_000,
    record_arrivals: bool = False,
    fail_worker: int | None = None,
    fail_round: int | None = None,
    detect_rounds: int = 2,
    chunk_base: int = 0,
    job: int = 0,
    now_base: int = 0,
):
    """Batch-per-round all-reduce driver over an unreliable fabric.

    ``switch`` is a ``BatchedDataplane`` (one jitted dispatch per round: every
    eligible (worker, chunk) packet that survives the i.i.d. request drop) or
    any object with a legacy per-packet ``.ingest`` (``core.switch.FpisaSwitch``
    — same round-synchronous schedule, one packet at a time). Both paths
    consume the seeded RNG identically (request drops drawn as one vector per
    round, per-worker result-delivery drops drawn per completion in packet
    order), so for identical seeds the two are **bit-identical** end to end —
    the parity the fig10 benchmark and tests/test_switchsim.py pin.

    Eligibility is snapshotted at round start: worker w may send chunk c iff
    it lacks c's result and holds the result of c - window (SwitchML's
    self-clocked streaming window, which makes slot recycling safe).

    Returns the aggregated (N,) vector; with ``record_arrivals`` (batched
    path only) also a {chunk: [workers in acceptance order]} dict for
    replaying the exact switch-arrival order through the jnp reference.

    Fault injection: with ``fail_worker``/``fail_round`` set, that worker
    crashes at the start of that round — it stops sending, and no result
    delivery is owed to it. ``detect_rounds`` rounds later the control plane's
    heartbeat timeout fires and ``switch.reclaim_worker`` frees its parked
    slots; the survivors' normal retransmissions (their shadow copies) then
    resubmit the reset chunks and the aggregation completes as a live-worker
    sum. Chunks whose slots completed before the death keep the dead worker's
    contribution (their cached results are re-served unchanged). The fault
    path consumes the shared RNG stream identically for every switch type, so
    per-packet/batched/numpy runs stay bit-identical under injected failures.

    ``chunk_base`` offsets the on-wire chunk ids so one switch can carry many
    consecutive calls (e.g. one per training step) without its slot state
    going stale: chunk ids stay monotonic across calls, which is exactly the
    SwitchML recycling discipline. State carried over from the previous call
    is recycled naturally as the new chunks claim slots.

    ``job`` tags every packet with that tenant id on a multi-tenant switch
    (this driver streams ONE job's traffic; ``tenancy.run_multitenant``
    interleaves several). ``now_base`` offsets the staleness clock the same
    way ``chunk_base`` offsets chunk ids, so consecutive calls against a
    shared switch keep aging the other tenants' slots; the clock reached is
    left on ``switch.last_now``.
    """
    cfg = switch.cfg
    w, n = worker_vectors.shape
    ports = getattr(cfg, "ports", None)
    assert w == (ports[job] if ports is not None else cfg.num_workers)
    e = cfg.elems_per_packet
    if hasattr(cfg, "job_window"):
        window = cfg.job_window(job)
    else:
        window = cfg.num_slots * getattr(cfg, "num_pipelines", 1)
    pad = (-n) % e
    vecs = np.pad(worker_vectors, ((0, 0), (0, pad))).astype(np.float32)
    nchunks = vecs.shape[1] // e
    vecs3 = vecs.reshape(w, nchunks, e)
    rng = np.random.default_rng(seed)
    batched = hasattr(switch, "ingest_batch")

    out = np.zeros((nchunks, e), np.float32)
    have_result = np.zeros((w, nchunks), bool)
    arrivals: dict[int, list[int]] = {}

    sp = _trace.span("switchsim.run_aggregation", phase="switch",
                     workers=w, nchunks=nchunks, job=job,
                     batched=batched, drop_prob=drop_prob)
    with sp:
        rnd = _drive_rounds(
            switch, vecs3, out, have_result, arrivals, rng,
            drop_prob=drop_prob, max_rounds=max_rounds, window=window,
            record_arrivals=record_arrivals, fail_worker=fail_worker,
            fail_round=fail_round, detect_rounds=detect_rounds,
            chunk_base=chunk_base, job=job, now_base=now_base,
            batched=batched)
        if sp:
            sp.tag(rounds=rnd + 1)
    switch.last_now = now_base + rnd  # staleness clock for the next caller
    flat = out.reshape(-1)[:n]
    if record_arrivals:
        return flat, arrivals
    return flat


def _drive_rounds(switch, vecs3, out, have_result, arrivals, rng, *,
                  drop_prob, max_rounds, window, record_arrivals,
                  fail_worker, fail_round, detect_rounds, chunk_base, job,
                  now_base, batched):
    """The round-synchronous loop of ``run_aggregation`` (same RNG stream,
    split out so the driver's trace span wraps exactly the wire time)."""
    w, nchunks, e = vecs3.shape
    reclaim_at: int | None = None
    for rnd in range(max_rounds):
        if fail_round is not None and rnd == fail_round and fail_worker is not None:
            # the worker crashes: it stops sending and is owed no delivery
            have_result[fail_worker, :] = True
            reclaim_at = rnd + detect_rounds  # heartbeat timeout fires then
        if reclaim_at is not None and rnd >= reclaim_at:
            switch.reclaim_worker(fail_worker, job)
            reclaim_at = None
        if have_result.all():
            break
        elig = ~have_result
        if nchunks > window:
            elig[:, window:] &= have_result[:, :-window]
        ws, cs = np.nonzero(elig)  # row-major: worker-major packet order
        keep = rng.random(ws.size) >= drop_prob
        ws, cs = ws[keep], cs[keep]
        if ws.size == 0:
            continue
        payloads = vecs3[ws, cs]
        if batched:
            ready, results, accepted = switch.ingest_batch(
                ws, cs + chunk_base, payloads,
                jobs=np.full(ws.size, job, np.int32), now=now_base + rnd)
            if record_arrivals:
                for i in np.nonzero(accepted)[0]:
                    arrivals.setdefault(int(cs[i]), []).append(int(ws[i]))
        else:
            from repro.core import switch as legacy

            ready = np.zeros(ws.size, bool)
            results = np.zeros((ws.size, e), np.float32)
            for i in range(ws.size):
                res = switch.ingest(
                    legacy.Packet(int(ws[i]), int(cs[i]) + chunk_base, payloads[i]),
                    job=job, now=now_base + rnd)
                if res is not None:
                    ready[i] = True
                    results[i] = res.payload
        for i in np.nonzero(ready)[0]:
            c = int(cs[i])
            out[c] = results[i]
            # vectorized but stream-identical to per-worker rng.random()
            # calls guarded by `not have_result` (Generator.random(n) draws
            # the same sequence as n scalar draws)
            miss = np.nonzero(~have_result[:, c])[0]
            if miss.size:
                ok = rng.random(miss.size) >= drop_prob
                have_result[miss[ok], c] = True
    if not have_result.all():
        raise RuntimeError("aggregation did not complete within max_rounds")
    return rnd
