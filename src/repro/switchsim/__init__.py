"""Batched, jit-compiled multi-pipeline PISA switch dataplane.

The per-packet emulator in ``core/switch.py`` is the *protocol reference*:
a Python state machine dispatching tiny jnp ops one packet at a time. This
subsystem is the *throughput engine*: the same FPISA slot semantics
(claim/recycle, bitmap idempotence, completion detection, delayed
renormalization) expressed as stacked array state and a single jitted
``ingest_batch`` that processes thousands of packets per dispatch, across
``num_pipelines`` independent ingress pipelines (the paper's Tofino pipeline
model, Sec. 4/6.1).

Modules
-------
``dataplane``  — ``DataplaneConfig`` / ``BatchedDataplane`` /
                 ``run_aggregation`` (the batch-per-round all-reduce driver,
                 which also drives the legacy per-packet switch for parity).
``query``      — batched in-switch query operators (Top-N compare kernel,
                 group-by scatter-accumulate kernel) used by ``db/query.py``.
``tenancy``    — multi-tenant sharing of one dataplane: the J-job round
                 driver ``run_multitenant``, Jain fairness, and the named
                 shared-dataplane registry behind the ``switch_emu``
                 strategy's tenancy wiring (DESIGN.md §10).

``core/switch.py`` remains the compatibility shim: its ``FpisaSwitch`` is now
a one-packet-at-a-time view over a single-pipeline ``BatchedDataplane``.
"""
from repro.switchsim.dataplane import (  # noqa: F401
    BatchedDataplane,
    DataplaneConfig,
    DataplaneState,
    NumpyDataplane,
    ingest_batch,
    init_state,
    lottery_pref,
    reclaim_dead_worker,
    run_aggregation,
    slot_of,
    slot_of_tenant,
)
from repro.switchsim.tenancy import (  # noqa: F401
    jain_fairness,
    reset_shared_dataplanes,
    run_multitenant,
    shared_dataplane,
    shared_emulated_allreduce,
)
