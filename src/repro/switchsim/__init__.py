"""Batched, jit-compiled multi-pipeline PISA switch dataplane.

The per-packet emulator in ``core/switch.py`` is the *protocol reference*:
a Python state machine dispatching tiny jnp ops one packet at a time. This
subsystem is the *throughput engine*: the same FPISA slot semantics
(claim/recycle, bitmap idempotence, completion detection, delayed
renormalization) expressed as stacked array state and a single jitted
``ingest_batch`` that processes thousands of packets per dispatch, across
``num_pipelines`` independent ingress pipelines (the paper's Tofino pipeline
model, Sec. 4/6.1).

Modules
-------
``dataplane``  — ``DataplaneConfig`` / ``BatchedDataplane`` /
                 ``run_aggregation`` (the batch-per-round all-reduce driver,
                 which also drives the legacy per-packet switch for parity).
``query``      — batched in-switch query operators (Top-N compare kernel,
                 group-by scatter-accumulate kernel) used by ``db/query.py``.
``tenancy``    — multi-tenant sharing of one dataplane: the J-job round
                 driver ``run_multitenant``, Jain fairness, and the named
                 shared-dataplane registry behind the ``switch_emu``
                 strategy's tenancy wiring (DESIGN.md §10).

``core/switch.py`` remains the compatibility shim: its ``FpisaSwitch`` is now
a one-packet-at-a-time view over a single-pipeline ``BatchedDataplane``.

Shared structural constants
---------------------------
``COUNTERS`` and ``SLOT_STATE_FIELDS`` are defined HERE, once, and imported
by all three dataplanes (batched jit, numpy mirror, per-packet shim). They
are the mirror contract: the ``mirror-parity`` lint rule
(tools/repro_lint) checks that no mirror re-defines them as literals and
that each dataplane's state layout matches, so a counter or slot-state
field added to one implementation cannot silently drift from the others.
They must stay above the submodule imports below — ``dataplane`` imports
them back from this (partially-initialized) package at import time.
"""
# per-job dataplane counters, in on-wire index order (the counters plane is
# (num_jobs, len(COUNTERS)) in every implementation)
COUNTERS = ("packets", "duplicates", "stale", "overwrite", "overflow",
            "reclaimed", "admission_denied", "preempted")

# per-slot/per-plane state fields, in DataplaneState order. The jitted
# dataplane carries them as NamedTuple fields; the numpy mirror as the
# underscore-prefixed attributes (``exp`` -> ``self._exp``).
SLOT_STATE_FIELDS = ("exp", "man", "seen", "slot_chunk", "result",
                     "result_valid", "counters", "recirc", "live",
                     "slot_job", "last_touch")

from repro.switchsim.dataplane import (  # noqa: E402,F401
    BatchedDataplane,
    DataplaneConfig,
    DataplaneState,
    NumpyDataplane,
    ingest_batch,
    init_state,
    lottery_pref,
    reclaim_dead_worker,
    run_aggregation,
    slot_of,
    slot_of_tenant,
)
from repro.switchsim.tenancy import (  # noqa: E402,F401
    jain_fairness,
    reset_shared_dataplanes,
    run_multitenant,
    shared_dataplane,
    shared_emulated_allreduce,
)
