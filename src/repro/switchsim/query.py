"""Batched in-switch query operators (paper Sec. 6) on the jitted dataplane.

``db/query.py`` used to push rows through Python loops dispatching tiny jnp
ops; these kernels stream row *batches* through the same vectorized FPISA
machinery as the all-reduce dataplane:

* ``topn_keep`` — one fused dispatch per row batch: encode the column,
  broadcast the threshold planes, FPISA compare (subtract + sign test,
  integer-only) — the switch-side half of Cheetah-style Top-N pruning.
* ``groupby_ingest`` — scatter-accumulate a (keys, values) row batch into
  per-group FPISA accumulator slots with *per-slot sequential semantics*
  (rows of the same key apply in batch order), using the same rank/round
  table as ``dataplane.ingest_batch``. Carries a per-slot ``since_flush``
  counter and renormalize+re-encode flushes the register every
  ``flush_every`` adds (the paper's Sec. 3.3 headroom bound: ~128 same-scale
  adds fit 7 headroom bits; flushing at 64 keeps a 2x margin).

Group-by uses the ``full`` FPISA add by default — the paper notes query
aggregation needs the RSAW extension rather than the FPISA-A approximation
(Sec. 6.1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import fpisa

from repro.switchsim.dataplane import _rank_table


@functools.partial(jax.jit, static_argnames=("fmt_name",))
def topn_keep(values, thresh_exp, thresh_man, *, fmt_name: str = "fp32"):
    """(B,) packed FP column vs scalar threshold planes -> (B,) bool keep mask
    (value > threshold), computed as FPISA subtraction + sign test."""
    fmt = fpisa.FORMATS[fmt_name]
    planes = fpisa.encode(values, fmt)
    t_exp = jnp.broadcast_to(thresh_exp, planes.exp.shape)
    t_man = jnp.broadcast_to(-thresh_man, planes.man.shape)
    diff, _ = fpisa.fpisa_add_full(planes, fpisa.Planes(t_exp, t_man), fmt)
    return diff.man > 0


@functools.partial(
    jax.jit, static_argnames=("num_slots", "rounds", "variant", "flush_every", "fmt_name"))
def groupby_ingest(exp, man, since, keys, values, valid, *, num_slots: int,
                   rounds: int, variant: str = "full", flush_every: int = 64,
                   fmt_name: str = "fp32"):
    """Accumulate a row batch into per-group FPISA slots.

    Args:
      exp/man:  (S,) int32 accumulator planes (S = num_slots).
      since:    (S,) int32 adds since the slot's last flush.
      keys:     (B,) int32 group keys in [0, S).
      values:   (B,) packed FP column.
      valid:    (B,) bool row mask.
      rounds:   static: max rows of one key this call applies (>= the batch's
                max per-key multiplicity, or the remainder is deferred).

    Returns (exp, man, since, deferred)."""
    fmt = fpisa.FORMATS[fmt_name]
    add = fpisa.fpisa_add_full if variant == "full" else fpisa.fpisa_a_add
    planes = fpisa.encode(values, fmt)
    table, deferred = _rank_table(keys, valid, num_slots, rounds)

    def round_body(carry, pidx):
        exp, man, since = carry
        active = pidx >= 0
        pi = jnp.where(active, pidx, 0)
        inp = fpisa.Planes(planes.exp[pi], planes.man[pi])
        newp, _ = add(fpisa.Planes(exp, man), inp, fmt)
        exp = jnp.where(active, newp.exp, exp)
        man = jnp.where(active, newp.man, man)
        since = jnp.where(active, since + 1, since)
        # periodic flush: renormalize + re-encode the register so long-running
        # slots never exhaust the int32 headroom. A flush fires at most once
        # per flush_every adds per slot, so skip the renorm work on the ~98%
        # of rounds where no slot is due.
        flush = since >= flush_every
        def do_flush(exp, man, since):
            fp = fpisa.encode(fpisa.renormalize(fpisa.Planes(exp, man), fmt), fmt)
            return (jnp.where(flush, fp.exp, exp), jnp.where(flush, fp.man, man),
                    jnp.where(flush, 0, since))
        exp, man, since = lax.cond(
            jnp.any(flush), do_flush, lambda e, m, s: (e, m, s), exp, man, since)
        return (exp, man, since), None

    (exp, man, since), _ = lax.scan(round_body, (exp, man, since), table.T)
    return exp, man, since, deferred
