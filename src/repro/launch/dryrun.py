import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS_EXTRA", "")  # noqa: E501 — MUST be the first two lines, before any jax-touching import

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract roofline terms from the compiled artifact."""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import traceback  # noqa: E402
from time import perf_counter  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, shape_applicable, ARCH_NAMES  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.core.agg import AggConfig, add_agg_args  # noqa: E402
from repro.trace import add_trace_args  # noqa: E402
from repro.trace import from_args as trace_from_args  # noqa: E402
from repro.launch import hloscan  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh  # noqa: E402
from repro.models.registry import build  # noqa: E402
from repro.optim import optimizers  # noqa: E402
from repro.sharding import rules  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402

COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+\[[^\]]*\]\S*)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute-start|collective-permute)\(",
)
GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, total_devices: int):
    """Per-device wire-byte estimate per collective category + op census."""
    out = {"ops": [], "wire_bytes_per_device": 0.0, "by_kind": {}}
    for line in hlo_text.splitlines():
        m = COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        size = _shape_bytes(type_str)  # per-device output bytes
        k = total_devices
        gm = GROUPS_IOTA_RE.search(line)
        if gm:
            k = int(gm.group(2))
        else:
            gl = GROUPS_LIST_RE.search(line)
            if gl:
                k = len(gl.group(1).split(","))
        if k <= 1:
            continue
        if kind == "all-reduce":
            wire = size * 2 * (k - 1) / k
        elif kind == "all-gather":
            wire = size * (k - 1) / k  # size is the gathered output
        elif kind == "reduce-scatter":
            wire = size * (k - 1)  # size is the scattered output
        elif kind == "all-to-all":
            wire = size * (k - 1) / k
        else:  # collective-permute
            wire = size
        out["ops"].append({"kind": kind, "bytes": size, "group": k, "wire": wire})
        out["wire_bytes_per_device"] += wire
        agg = out["by_kind"].setdefault(kind, {"count": 0, "wire": 0.0})
        agg["count"] += 1
        agg["wire"] += wire
    return out


def model_flops(cfg, shape: ShapeConfig) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D for inference."""
    n = active_param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n * tokens


def active_param_count(cfg) -> float:
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        di = cfg.ssm_d_inner
        per = cfg.d_model * (2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads) + di * cfg.d_model
        return cfg.num_layers * per + cfg.vocab_size * cfg.d_model * 2
    attn = cfg.d_model * hd * cfg.num_heads * 2 + cfg.d_model * hd * cfg.num_kv_heads * 2
    if cfg.family == "moe":
        ff = cfg.num_experts_per_token * 3 * cfg.d_model * cfg.d_ff
        if cfg.moe_dense_ff:
            ff += 3 * cfg.d_model * cfg.moe_dense_ff
    elif cfg.family == "hybrid":
        di = cfg.ssm_d_inner
        mamba = cfg.d_model * (2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads) + di * cfg.d_model
        ng = cfg.num_layers // cfg.hybrid_attn_every
        shared = ng * (attn + 3 * cfg.d_model * cfg.d_ff)
        return cfg.num_layers * mamba + shared + cfg.vocab_size * cfg.d_model * 2
    else:
        ff = 3 * cfg.d_model * cfg.d_ff
    layers = cfg.num_layers * (attn + ff)
    if cfg.is_encoder_decoder:
        layers += cfg.num_encoder_layers * (attn + 3 * cfg.d_model * cfg.d_ff)
        layers += cfg.num_layers * attn  # cross attention
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return layers + emb


def build_cell(arch: str, shape_name: str, mesh,
               agg: AggConfig | None = None,
               overrides: dict | None = None):
    """Returns (jitted fn, kwargs of ShapeDtypeStructs with shardings)."""
    agg = agg or AggConfig()
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    model = build(cfg)
    nd = mesh.devices.size

    p_sds = S.param_specs(model)
    pspecs = rules.param_pspecs(p_sds, cfg, mesh)
    p_shard = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        p_sds, pspecs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )

    batch_sds = S.input_specs(cfg, shape)
    bspecs = rules.input_pspecs(batch_sds, mesh, shape.global_batch)
    b_shard = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        batch_sds, bspecs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )

    if shape.kind == "train":
        opt_cfg = optimizers.OptConfig(name=cfg.optimizer)
        o_sds = S.opt_specs(p_sds, opt_cfg)
        ospecs = rules.opt_pspecs(pspecs, p_sds, mesh)
        o_shard = optimizers.OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P())),
            m=jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
                o_sds.m, ospecs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            ),
            v=None if o_sds.v is None else jax.tree.map(
                lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
                o_sds.v, ospecs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            ),
        )
        step = make_train_step(model, mesh, agg, opt_cfg, shape.global_batch,
                               accum_steps=cfg.accum_steps)
        # donate params + optimizer state: in-place update, halves peak memory
        return jax.jit(step, donate_argnums=(0, 1)), (p_shard, o_shard, b_shard)

    cache_sds = S.cache_specs(model, shape.global_batch, shape.seq_len)
    cspecs = rules.cache_pspecs(cache_sds, mesh, shape.global_batch, cfg)
    c_shard = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, sp))
        if hasattr(s, "shape") else s,
        cache_sds, cspecs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    if shape.kind == "prefill":
        # donate the cache: prefill writes it in place
        fn = jax.jit(lambda p, b, c: build(cfg).prefill(p, b, c), donate_argnums=(2,))
        return fn, (p_shard, b_shard, c_shard)
    # decode: cache updated in place every step
    fn = jax.jit(lambda p, t, c: build(cfg).decode_step(p, t, c), donate_argnums=(2,))
    return fn, (p_shard, b_shard["tokens"], c_shard)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             agg: AggConfig | None = None,
             overrides: dict | None = None,
             save_hlo: str | None = None) -> dict:
    agg = agg or AggConfig()
    mesh = make_production_mesh(multi_pod=multi_pod)
    nd = mesh.devices.size
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mesh": dict(mesh.shape), "agg": agg.strategy, "status": "ok",
        "overrides": overrides or {}, "wire_bits": agg.wire_bits,
        "pod_wire_bits": agg.pod_wire_bits,
    }
    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = "long_500k requires sub-quadratic attention (see DESIGN.md)"
        return rec
    t0 = perf_counter()
    try:
        jax.sharding.set_mesh(mesh)  # enables in-model sharding hints
        fn, args = build_cell(arch, shape_name, mesh, agg, overrides)
        lowered = fn.lower(*args)
        t_lower = perf_counter() - t0
        compiled = lowered.compile()
        t_compile = perf_counter() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        # hloscan handles while-loop (lax.scan) trip-count multiplication,
        # which XLA's own cost analysis does not (see hloscan module doc).
        an = hloscan.analyze(hlo, nd)

        flops_dev = an.flops
        bytes_dev = an.hbm_bytes
        compute_t = flops_dev / PEAK_FLOPS_BF16
        memory_t = bytes_dev / HBM_BW
        coll_t = an.wire_bytes / ICI_BW
        mf = model_flops(cfg, shape)
        rec.update({
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "per_device": {
                "arg_bytes": ma.argument_size_in_bytes,
                "out_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "peak_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes
                               + ma.output_size_in_bytes - ma.alias_size_in_bytes,
                "hlo_flops": flops_dev,
                "hlo_bytes": bytes_dev,
                "coll_wire_bytes": an.wire_bytes,
                "xla_cost_flops_unscaled": float(ca.get("flops", 0.0)),
            },
            "roofline": {
                "compute_s": compute_t,
                "memory_s": memory_t,
                "collective_s": coll_t,
                "bottleneck": max(
                    ("compute", compute_t), ("memory", memory_t), ("collective", coll_t),
                    key=lambda kv: kv[1],
                )[0],
            },
            "model_flops_global": mf,
            "useful_flops_ratio": (mf / (flops_dev * nd)) if flops_dev else None,
            "collectives_by_kind": an.collectives,
        })
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — a failed cell is a finding, not a crash
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    add_agg_args(ap)  # shared --agg-* flags (repro.core.agg); --wire-bits /
    #                   --pod-wire-bits / --agg kept as aliases
    add_trace_args(ap)  # the shared --trace-* flags (repro.trace)
    ap.add_argument("--out", default=None, help="append JSON lines here")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (value parsed as python literal)")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            import ast

            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    try:
        agg = AggConfig.from_args(args)
    except ValueError as e:
        ap.error(str(e))
    archs = ARCH_NAMES if args.arch in (None, "all") else [args.arch]
    shapes = list(SHAPES) if args.shape in (None, "all") else [args.shape]
    session = trace_from_args(args)
    try:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, args.multi_pod, agg,
                               overrides or None, args.save_hlo)
                line = json.dumps(rec)
                print(line, flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
    finally:
        session.finish()


if __name__ == "__main__":
    main()
