"""HLO-text analyzer: FLOPs / HBM bytes / collective wire bytes with correct
while-loop (lax.scan) trip-count multiplication.

XLA's HloCostAnalysis visits a while body ONCE, so compiled.cost_analysis()
undercounts scan-over-layers programs by ~num_layers x (verified in
EXPERIMENTS.md §Dry-run notes). This module re-derives the three roofline
inputs from compiled.as_text():

  flops   : 2 * prod(out_dims) * prod(contracting_dims) per dot, + 1/elem for
            elementwise ops inside fusions, multiplied through nested whiles.
  hbm     : sum of (operands + outputs) bytes of top-level ops at fusion
            granularity (fusion internals don't touch HBM), same multipliers.
  wire    : per-device collective bytes with ring-model factors
            (all-reduce 2(k-1)/k, gather/scatter/all-to-all (k-1)/k,
            permute 1), same multipliers.

Conventions are documented in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")
DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
INSTR_RE = re.compile(r"^\s+(ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
OPCODE_AFTER_TYPE_RE = re.compile(r"^\s*([\w\-]+)\(")


def _parse_rhs(rhs: str):
    """Split '<type> <opcode>(rest' handling tuple types that contain
    /*index=N*/ comments (so pure regex on '=' fails). Returns
    (type_str, opcode, rest) or None."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth = 0
        for pos, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rhs[: pos + 1]
                    tail = rhs[pos + 1:]
                    m = OPCODE_AFTER_TYPE_RE.match(tail)
                    if not m:
                        return None
                    return type_str, m.group(1), tail[m.end():]
        return None
    m = re.match(r"^([\w\[\],{}]+)\s+([\w\-]+)\(", rhs)
    if not m:
        return None
    return m.group(1), m.group(2), rhs[m.end():]
COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.*?)\s*\{\s*$")
TRIP_RE = re.compile(r'known_trip_count[\\"=:{]+n[\\":]+(\d+)')
GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "after-all", "partition-id", "replica-id", "iota",
}
COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}
ELEMENTWISE_FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "compare", "select", "and", "or", "xor", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "floor", "ceil",
    "round-nearest-afz", "sign", "convert", "cosine", "sine", "logistic",
    "reduce", "reduce-window", "clamp", "remainder", "atan2", "expm1", "log1p",
}


def shape_elems(type_str: str) -> int:
    total = 0
    for m in SHAPE_RE.finditer(type_str):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n
    return total


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in SHAPE_RE.finditer(type_str):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[m.group(1)]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # everything after the opcode's '('
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]  # instr/param name -> type string


def _split_params(sig: str) -> List[tuple]:
    """'a: f32[2], b: (s32[], f32[3])' -> [(a, 'f32[2]'), (b, '(s32[], f32[3])')]."""
    out, depth, cur = [], 0, ""
    for ch in sig:
        if ch == "," and depth == 0:
            out.append(cur)
            cur = ""
            continue
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        cur += ch
    if cur.strip():
        out.append(cur)
    pairs = []
    for item in out:
        if ":" in item:
            nm, ty = item.split(":", 1)
            pairs.append((nm.strip().lstrip("%"), ty.strip()))
    return pairs


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        hdr = COMP_HDR_RE.match(line)
        if hdr:
            cur = Computation(name=hdr.group(2), instrs=[], symbols={})
            for nm, ty in _split_params(hdr.group(3)):
                cur.symbols[nm] = ty
            comps[cur.name] = cur
            if hdr.group(1):
                comps["__entry__"] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(2), m.group(3)
        parsed = _parse_rhs(rhs)
        if parsed is None:
            continue
        type_str, opcode, rest = parsed
        cur.symbols[name] = type_str
        cur.instrs.append(Instr(name=name, type_str=type_str, opcode=opcode,
                                rest=rest, line=line))
    return comps


def _operands(instr: Instr) -> List[str]:
    """Names of %operands inside the call parens (first balanced group)."""
    depth, out, cur = 1, [], ""
    for ch in instr.rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                out.append(cur)
                break
        if depth >= 1:
            cur += ch if ch != "," or depth > 1 else "\x00"
    parts = "".join(out).split("\x00") if out else []
    names = []
    for p in parts:
        mm = re.search(r"%([\w.\-]+)", p)
        if mm:
            names.append(mm.group(1))
    return names


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_elems = shape_elems(instr.type_str)
    ops = _operands(instr)
    contract = 1
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    if cm and ops:
        lhs_ty = comp.symbols.get(ops[0], "")
        dims = _shape_dims(lhs_ty)
        for idx in cm.group(1).split(","):
            if idx != "" and int(idx) < len(dims):
                contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def _trip_count(instr: Instr, comps: Dict[str, Computation]) -> int:
    t = TRIP_RE.search(instr.line)
    if t:
        return int(t.group(1))
    cm = re.search(r"condition=%?([\w.\-]+)", instr.line)
    if cm and cm.group(1) in comps:
        consts = [
            int(c)
            for i in comps[cm.group(1)].instrs
            for c in re.findall(r"constant\((\d+)\)", i.line)
        ]
        if consts:
            return max(consts)
    return 1


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)

    def add_collective(self, kind: str, wire: float, count: float):
        agg = self.collectives.setdefault(kind, {"count": 0.0, "wire": 0.0})
        agg["count"] += count
        agg["wire"] += wire


def _fusion_flops(comp: Computation, comps) -> float:
    total = 0.0
    for i in comp.instrs:
        if i.opcode == "dot":
            total += _dot_flops(i, comp)
        elif i.opcode == "fusion":
            cm = re.search(r"calls=%?([\w.\-]+)", i.line)
            if cm and cm.group(1) in comps:
                total += _fusion_flops(comps[cm.group(1)], comps)
        elif i.opcode in ELEMENTWISE_FLOP:
            total += shape_elems(i.type_str)
    return total


def _sliced_param_indices(called: Computation) -> Dict[int, str]:
    """Parameter index -> slice-result type for fusion parameters whose only
    in-fusion use begins with a (dynamic-)slice/gather — those reads touch
    slice-output bytes, not the whole operand (e.g. per-layer reads of a
    stacked scan carry)."""
    pname_to_idx: Dict[str, int] = {}
    uses: Dict[str, list] = {}
    for ins in called.instrs:
        if ins.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", ins.line)
            if pm:
                pname_to_idx[ins.name] = int(pm.group(1))
        else:
            for o in _operands(ins):
                uses.setdefault(o, []).append(ins)
    out: Dict[int, str] = {}
    for pname, idx in pname_to_idx.items():
        u = uses.get(pname, [])
        if not u:
            continue
        if all(x.opcode in ("dynamic-slice", "slice", "gather") for x in u):
            out[idx] = u[0].type_str
        elif all(
            x.opcode == "dynamic-update-slice" and _operands(x) and _operands(x)[0] == pname
            for x in u
        ):
            # in-place update target: traffic = the update slice, not the stack
            ops0 = _operands(u[0])
            out[idx] = called.symbols.get(ops0[1], "") if len(ops0) > 1 else ""
    return out


def _fusion_bytes(instr: Instr, comp: Computation, comps, cache: dict) -> float:
    """Output bytes + operand bytes, with sliced-inside params charged at
    slice-output size."""
    key = None
    cm = re.search(r"calls=%?([\w.\-]+)", instr.line)
    sliced: Dict[int, str] = {}
    if cm and cm.group(1) in comps:
        key = "bytes::" + cm.group(1)
        if key not in cache:
            cache[key] = _sliced_param_indices(comps[cm.group(1)])
        sliced = cache[key]
    total = shape_bytes(instr.type_str)
    for idx, o in enumerate(_operands(instr)):
        if idx in sliced:
            total += shape_bytes(sliced[idx])
        else:
            total += shape_bytes(comp.symbols.get(o, ""))
    return total


def _wire_factor(kind: str, size: float, k: int) -> float:
    if kind == "all-reduce":
        return size * 2 * (k - 1) / k
    if kind == "all-gather":
        return size * (k - 1) / k
    if kind == "reduce-scatter":
        return size * (k - 1)
    if kind == "all-to-all":
        return size * (k - 1) / k
    return size  # collective-permute


def _analyze(comp: Computation, comps, mult: float, total_devices: int,
             acc: Analysis, seen_fusion_cache: dict):
    for i in comp.instrs:
        op = i.opcode
        if op == "while":
            body = re.search(r"body=%?([\w.\-]+)", i.line)
            trip = _trip_count(i, comps)
            if body and body.group(1) in comps:
                _analyze(comps[body.group(1)], comps, mult * trip,
                         total_devices, acc, seen_fusion_cache)
            continue
        if op in ("call", "async-start"):
            cm = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", i.line)
            if cm and cm.group(1) in comps:
                _analyze(comps[cm.group(1)], comps, mult, total_devices, acc,
                         seen_fusion_cache)
            continue
        if op == "conditional":
            for cm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w.\-]+)|false_computation=%?([\w.\-]+))", i.line):
                names = [n for n in re.findall(r"%?([\w.\-]+)", cm.group(0)) if n in comps]
                for n in names[:1]:
                    _analyze(comps[n], comps, mult, total_devices, acc,
                             seen_fusion_cache)
            continue

        base_kind = op.replace("-start", "")
        if base_kind in {"all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"} and op != "all-reduce-done":
            size = shape_bytes(i.type_str)
            k = total_devices
            gm = GROUPS_IOTA_RE.search(i.line)
            if gm:
                k = int(gm.group(2))
            else:
                gl = GROUPS_LIST_RE.search(i.line)
                if gl:
                    k = len(gl.group(1).split(","))
            if k > 1:
                wire = _wire_factor(base_kind, size, k) * mult
                acc.wire_bytes += wire
                acc.add_collective(base_kind, wire, mult)
            # collectives also move HBM bytes
            acc.hbm_bytes += shape_bytes(i.type_str) * 2 * mult
            continue

        if op == "dot":
            acc.flops += _dot_flops(i, comp) * mult
        elif op == "fusion":
            cm = re.search(r"calls=%?([\w.\-]+)", i.line)
            if cm:
                key = cm.group(1)
                if key not in seen_fusion_cache:
                    seen_fusion_cache[key] = (
                        _fusion_flops(comps[key], comps) if key in comps else 0.0
                    )
                acc.flops += seen_fusion_cache[key] * mult
        elif op in ELEMENTWISE_FLOP:
            acc.flops += shape_elems(i.type_str) * mult

        if op not in SKIP_BYTES_OPS:
            if op in ("dynamic-slice", "slice", "gather", "broadcast", "concatenate", "pad", "reshape", "transpose", "copy", "reverse"):
                # slicing/layout ops read ~output-sized data, not the full
                # operand (a dynamic-slice of a scan carry must not be charged
                # the whole carry every iteration)
                acc.hbm_bytes += 2 * shape_bytes(i.type_str) * mult
            elif op in ("dynamic-update-slice", "scatter"):
                ops_ = _operands(i)
                upd = shape_bytes(comp.symbols.get(ops_[1], "")) if len(ops_) > 1 else 0
                acc.hbm_bytes += 2 * upd * mult
            elif op == "fusion":
                acc.hbm_bytes += _fusion_bytes(i, comp, comps, seen_fusion_cache) * mult
            else:
                b = shape_bytes(i.type_str)
                for o in _operands(i):
                    b += shape_bytes(comp.symbols.get(o, ""))
                acc.hbm_bytes += b * mult


def analyze(hlo_text: str, total_devices: int) -> Analysis:
    comps = parse_module(hlo_text)
    acc = Analysis()
    if "__entry__" not in comps:
        return acc
    _analyze(comps["__entry__"], comps, 1.0, total_devices, acc, {})
    return acc
