"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No device allocation happens here: everything is abstract shapes, including
parameters (via jax.eval_shape over init) and serving caches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Batch inputs for train/prefill; decode adds tokens-only (cache comes
    from cache_specs)."""
    b, s = shape.global_batch, shape.seq_len
    act = cfg.activation_dtype
    if shape.kind == "decode":
        batch = {"tokens": sds((b, 1), jnp.int32)}
        return batch
    if cfg.family == "vlm":
        text = s - cfg.num_patches
        return {
            "tokens": sds((b, text), jnp.int32),
            "patch_embeds": sds((b, cfg.num_patches, cfg.d_model), act),
        }
    if cfg.is_encoder_decoder:
        return {
            "tokens": sds((b, s), jnp.int32),
            "frames": sds((b, cfg.num_frames, cfg.d_model), act),
        }
    return {"tokens": sds((b, s), jnp.int32)}


def param_specs(model) -> dict:
    return jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))


def cache_specs(model, batch: int, max_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def opt_specs(params_like, opt_cfg):
    from repro.optim import optimizers

    return jax.eval_shape(lambda p: optimizers.init(p, opt_cfg), params_like)
