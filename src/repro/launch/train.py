"""Training launcher: end-to-end loop with checkpoint/restart, health
monitoring, and FPISA gradient aggregation.

Usage (CPU-scale example — see examples/train_lm.py for a driver):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 50 --agg fpisa --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
from time import perf_counter

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.core.agg import AggConfig, add_agg_args
from repro.trace import add_trace_args
from repro.trace import from_args as trace_from_args
from repro.data.pipeline import ShardedLoader, SyntheticCorpus
from repro.models.registry import build, param_count
from repro.optim import optimizers
from repro.runtime import checkpoint as ckpt
from repro.runtime.elastic import make_mesh_for
from repro.runtime.health import HealthMonitor
from repro.sharding import rules
from repro.train.step import make_train_step


def train_loop(cfg, *, steps: int, global_batch: int, seq_len: int,
               agg: AggConfig | None = None,
               agg_strategy: str = "fpisa", agg_backend: str = "auto",
               agg_chunk: int = 0, agg_bucket_bytes: int = 0,
               ckpt_dir: str | None = None,
               ckpt_every: int = 50, mesh=None, log_every: int = 10,
               opt_overrides: dict | None = None, seed: int = 0):
    """Plain (non-elastic) training loop.

    Aggregation is configured by ONE ``AggConfig`` (``agg``); the loose
    ``agg_*`` keyword args are retained for backwards compatibility and are
    ignored when ``agg`` is given."""
    mesh = mesh or make_mesh_for()
    if agg is None:
        agg = AggConfig(strategy=agg_strategy, backend=agg_backend,
                        chunk_elems=agg_chunk, bucket_bytes=agg_bucket_bytes)
    model = build(cfg)
    opt_kw = {"name": cfg.optimizer, "lr": cfg.learning_rate}
    opt_kw.update(opt_overrides or {})
    opt_cfg = optimizers.OptConfig(**opt_kw)

    params = model.init(jax.random.PRNGKey(seed))
    pspecs = rules.param_pspecs(params, cfg, mesh)
    params = jax.device_put(params, rules.named(mesh, pspecs))
    opt_state = optimizers.init(params, opt_cfg)
    ospecs = rules.opt_pspecs(pspecs, params, mesh)
    opt_state = optimizers.OptState(
        step=jax.device_put(opt_state.step, NamedSharding(mesh, P())),
        m=jax.device_put(opt_state.m, rules.named(mesh, ospecs)),
        v=None if opt_state.v is None else jax.device_put(opt_state.v, rules.named(mesh, ospecs)),
    )

    start_step = 0
    saver = None
    if ckpt_dir:
        saver = ckpt.AsyncCheckpointer(ckpt_dir)
        latest = ckpt.latest_step(ckpt_dir)
        if latest is not None:
            try:
                # atomic bundle: params + opt always come from the SAME step
                trees, extra = ckpt.restore_bundle(
                    ckpt_dir, latest, {"params": params, "opt": opt_state})
                host_params, host_opt = trees["params"], trees["opt"]
            except ValueError:
                # pre-bundle layout (params at <dir>, opt at <dir>_opt) from
                # an older run — restore it once; the next save commits a
                # bundle and the split dirs stop mattering
                host_params, extra = ckpt.restore(ckpt_dir, latest, params)
                host_opt, _ = ckpt.restore(ckpt_dir + "_opt", latest, opt_state)
            params = jax.device_put(host_params, rules.named(mesh, pspecs))
            opt_state = optimizers.OptState(
                step=jax.device_put(host_opt.step, NamedSharding(mesh, P())),
                m=jax.device_put(host_opt.m, rules.named(mesh, ospecs)),
                v=None if host_opt.v is None else jax.device_put(host_opt.v, rules.named(mesh, ospecs)),
            )
            start_step = latest + 1
            print(f"[train] resumed from step {latest}")

    step_fn = jax.jit(make_train_step(model, mesh, agg, opt_cfg, global_batch))
    loader = ShardedLoader(SyntheticCorpus(cfg.vocab_size, seed), global_batch, seq_len)
    bspec = rules.batch_pspec(mesh, global_batch)
    health = HealthMonitor(hosts=[0])

    print(f"[train] {cfg.name}: {param_count(params)/1e6:.1f}M params, "
          f"mesh={dict(mesh.shape)}, agg={agg.strategy}")
    history = []
    for step in range(start_step, steps):
        t0 = perf_counter()
        batch = {"tokens": jax.device_put(
            loader.batch_at(step)["tokens"], NamedSharding(mesh, P(*bspec, None)))}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = perf_counter() - t0
        health.heartbeat(0, dt)
        history.append(loss)
        if step % log_every == 0 or step == steps - 1:
            tok_s = global_batch * seq_len / dt
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {tok_s:,.0f} tok/s")
        if saver and step > 0 and step % ckpt_every == 0:
            saver.save_bundle(step, {"params": params, "opt": opt_state},
                              {"loss": loss})
    if saver:
        saver.wait()
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    add_agg_args(ap)  # the shared --agg-* flags (repro.core.agg)
    add_trace_args(ap)  # the shared --trace-* flags (repro.trace)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fault-plan", default="",
                    help="fault-injection spec, e.g. 'kill:2@5' or "
                         "'kill:2@5,revive:2@20,slow:3@4x6' — routes the run "
                         "through the elastic controller "
                         "(repro/runtime/controller.py): heartbeats, switch-"
                         "slot reclamation, re-mesh + bit-identical resume")
    ap.add_argument("--num-hosts", type=int, default=None,
                    help="logical worker / host count for the elastic "
                         "controller (default: one per device); implies the "
                         "controller path even without --fault-plan")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    try:
        agg = AggConfig.from_args(args)
    except ValueError as e:
        ap.error(str(e))
    session = trace_from_args(args)
    try:
        if args.fault_plan or args.num_hosts:
            if agg.chunk_elems:
                ap.error("--agg-chunk is not supported on the elastic "
                         "controller path (stacked aggregation; use "
                         "--bucket-bytes instead)")
            from repro.runtime.controller import run_controller

            run_controller(cfg, steps=args.steps,
                           global_batch=args.global_batch,
                           seq_len=args.seq_len, agg=agg,
                           num_hosts=args.num_hosts, ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every,
                           fault_plan=args.fault_plan)
            return
        train_loop(cfg, steps=args.steps, global_batch=args.global_batch,
                   seq_len=args.seq_len, agg=agg,
                   ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    finally:
        session.finish()


if __name__ == "__main__":
    main()
