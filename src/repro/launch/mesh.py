"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run launcher must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


# TPU v5e-like target constants for the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
