"""Lightweight span tracer: nestable context-manager spans over
``time.perf_counter`` with a ring-buffer recorder (DESIGN.md §13).

The tracer exists so the per-phase costs of the aggregation pipeline —
encode / collective / finish in ``core/bucketer.py``, switchsim rounds,
serve prefill/decode, controller recovery — can be recorded from ordinary
runs and replayed by the cost-model autotuner (``repro.autotune``). Design
constraints, in order:

1. **Near-zero disabled path.** Instrumentation lives in hot loops that run
   with tracing off in production. ``span()`` with the tracer disabled is one
   attribute load, one bool test, and the return of a shared no-op singleton
   — no allocation, no clock read (bound pinned by tests/test_trace.py).
2. **Attribution through sync boundaries.** jax dispatch is asynchronous: a
   ``perf_counter`` pair around an eager op measures dispatch, not device
   work. A span therefore exposes ``sync(value)`` which calls
   ``jax.block_until_ready`` *inside* the span, so the device work lands in
   the span that issued it. Under a jit trace the values are abstract
   Tracers — sync detects that, skips the block, and leaves the span marked
   ``synced=False`` so the cost model can ignore trace-time artifacts.
3. **Bounded memory.** Spans land in a ``deque(maxlen=capacity)`` ring:
   long-running jobs keep the most recent ``capacity`` spans and never grow.

Spans are used in the ``with`` form only (enforced by the ``timing-
discipline`` lint rule — a bare ``.start()`` with a forgotten end corrupts
the nesting stack)::

    with trace.span("bucketer.encode", bucket=i, phase="encode") as sp:
        state = encode(buf)
        sp.sync(state)
"""
from __future__ import annotations

import itertools
import threading
from collections import deque
from time import perf_counter

SCHEMA_VERSION = 1

_DEFAULT_CAPACITY = 1 << 16


def _block_until_ready(value) -> bool:
    """Block on a pytree of device values; False when abstract (jit trace).

    jax is imported lazily so the tracer stays importable (and the switchsim
    host-callback paths stay jax-free) when no span ever syncs."""
    if value is None:
        return False
    import jax

    leaves = jax.tree_util.tree_leaves(value)
    if not leaves:
        return False
    if any(isinstance(leaf, jax.core.Tracer) for leaf in leaves):
        return False  # inside a jit trace: timings would be trace-time lies
    jax.block_until_ready(leaves)
    return True


class _NullSpan:
    """The disabled path: a shared, stateless no-op (falsy, so callers can
    gate expensive tag computation with ``if sp:``)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def __bool__(self):
        return False

    def tag(self, **tags):
        return self

    def sync(self, value):
        return value


NULL_SPAN = _NullSpan()


class Span:
    """One timed region. Context-manager only (see module doc)."""

    __slots__ = ("name", "tags", "sid", "parent", "depth", "tid",
                 "t0", "t1", "synced", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, tags: dict):
        self._tracer = tracer
        self.name = name
        self.tags = tags
        self.sid = -1
        self.parent = -1
        self.depth = 0
        self.tid = 0
        self.t0 = 0.0
        self.t1 = 0.0
        self.synced = False

    def __bool__(self):
        return True

    def tag(self, **tags) -> "Span":
        """Attach/overwrite tags after entry (e.g. counts known only at the
        end of the region)."""
        self.tags.update(tags)
        return self

    def sync(self, value):
        """Block until ``value`` (a jax pytree) is ready, attributing its
        device time to this span; marks the span ``synced``. No-op (and
        ``synced`` stays False) for abstract values under a jit trace."""
        if _block_until_ready(value):
            self.synced = True
        return value

    # -- context manager ---------------------------------------------------

    def __enter__(self) -> "Span":
        return self.start()

    def __exit__(self, exc_type, exc, tb):
        self.end()
        return False

    def start(self) -> "Span":
        # internal: callers use the ``with`` form (lint: timing-discipline)
        stack = self._tracer._stack()
        self.sid = next(self._tracer._ids)
        self.parent = stack[-1].sid if stack else -1
        self.depth = len(stack)
        self.tid = threading.get_ident()
        stack.append(self)
        self.t0 = perf_counter()
        return self

    def end(self) -> None:
        self.t1 = perf_counter()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:          # mismatched exits: unwind to self
            while stack and stack.pop() is not self:
                pass
        self._tracer._record(self)

    def to_dict(self) -> dict:
        return {
            "name": self.name, "id": self.sid, "parent": self.parent,
            "depth": self.depth, "tid": self.tid, "ts": self.t0,
            "dur": self.t1 - self.t0, "synced": self.synced,
            "tags": self.tags,
        }


class Tracer:
    """Ring-buffer span recorder. One global instance serves the module-level
    ``span()`` helper; tests and the autotune profiler may build private
    ones."""

    def __init__(self, capacity: int = _DEFAULT_CAPACITY, *,
                 active: bool = True):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = capacity
        self.active = bool(active)
        self._ring: deque = deque(maxlen=capacity)
        self._ids = itertools.count()
        self._local = threading.local()
        self.dropped = 0

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, sp: Span) -> None:
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(sp.to_dict())

    def span(self, name: str, **tags) -> Span | _NullSpan:
        if not self.active:
            return NULL_SPAN
        return Span(self, name, tags)

    @property
    def spans(self) -> list[dict]:
        """Recorded span dicts, oldest first."""
        return list(self._ring)

    def clear(self) -> None:
        self._ring.clear()
        self.dropped = 0


# ---------------------------------------------------------------------------
# the global tracer — what instrumented modules talk to
# ---------------------------------------------------------------------------

_GLOBAL = Tracer(active=False)


def span(name: str, **tags):
    """Open a span on the global tracer (``with trace.span(...) as sp:``).

    THE hot-path entry point: when tracing is disabled this is one attribute
    load + bool test + shared-singleton return."""
    tr = _GLOBAL
    if not tr.active:
        return NULL_SPAN
    return Span(tr, name, tags)


def enable(capacity: int = _DEFAULT_CAPACITY) -> Tracer:
    """Turn the global tracer on (fresh ring) and return it."""
    global _GLOBAL
    _GLOBAL = Tracer(capacity, active=True)
    return _GLOBAL


def disable() -> None:
    _GLOBAL.active = False


def enabled() -> bool:
    return _GLOBAL.active


def get() -> Tracer:
    """The current global tracer (inspect ``.spans`` after a traced run)."""
    return _GLOBAL
