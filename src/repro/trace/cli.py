"""CLI threading for the tracer — the ``add_agg_args`` pattern applied to
tracing: every entry point (launchers, examples, benchmarks) calls
``add_trace_args(parser)`` once and ``from_args(ns)`` after parsing, instead
of re-declaring ``--trace`` flags by hand::

    add_trace_args(ap)
    args = ap.parse_args()
    session = trace.from_args(args)
    ...                      # instrumented code records spans
    session.finish()         # writes --trace-out (JSONL, or chrome when the
                             # path ends in .chrome.json) and prints a line

``from_args`` enables the GLOBAL tracer, so instrumentation deep in
core/switchsim/serve/runtime records without any handle threading.
"""
from __future__ import annotations

import argparse

from repro.trace import export, tracer


def add_trace_args(parser: argparse.ArgumentParser):
    g = parser.add_argument_group("tracing", "span tracer (repro.trace)")
    g.add_argument(
        "--trace", action="store_true",
        help="record per-phase timing spans (agg/bucketer/switchsim/serve/"
             "runtime); implied by --trace-out")
    g.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write the recorded spans here on exit: JSONL with a schema "
             "header (feeds 'python -m repro.autotune' / --bucket-bytes "
             "auto), or chrome://tracing JSON when PATH ends in "
             ".chrome.json")
    g.add_argument(
        "--trace-capacity", type=int, default=tracer._DEFAULT_CAPACITY,
        metavar="N", help="ring-buffer capacity in spans (oldest dropped)")
    return g


class TraceSession:
    """Handle returned by :func:`from_args`; ``finish()`` flushes the file."""

    def __init__(self, enabled: bool, path: str | None, capacity: int):
        self.path = path
        self.enabled = enabled
        if enabled:
            self.tracer = tracer.enable(capacity)
        else:
            self.tracer = None

    def finish(self) -> str | None:
        """Write ``--trace-out`` (if any) and disable the global tracer.
        Returns the path written, or None."""
        if not self.enabled:
            return None
        tracer.disable()
        tr = self.tracer
        if self.path:
            if str(self.path).endswith(".chrome.json"):
                out = export.write_chrome(tr, self.path)
            else:
                out = export.write_jsonl(tr, self.path)
            print(f"trace: {len(tr.spans)} spans -> {out}"
                  + (f" ({tr.dropped} dropped)" if tr.dropped else ""))
            return out
        print(f"trace: {len(tr.spans)} spans recorded (no --trace-out; "
              f"inspect repro.trace.get().spans)")
        return None


def from_args(ns: argparse.Namespace) -> TraceSession:
    """Enable the global tracer when ``--trace``/``--trace-out`` was given."""
    path = getattr(ns, "trace_out", None)
    enabled = bool(getattr(ns, "trace", False) or path)
    capacity = getattr(ns, "trace_capacity", tracer._DEFAULT_CAPACITY)
    return TraceSession(enabled, path, capacity)
