"""Trace serialization: JSONL (the cost-model interchange format) and
chrome://tracing (the human one). Schema documented in DESIGN.md §13.

JSONL layout — line 1 is a header object carrying the schema version, every
following line is one span dict::

    {"schema": 1, "kind": "repro-trace", "clock": "perf_counter", ...}
    {"name": "bucketer.encode", "id": 3, "parent": 2, "depth": 1, ...}

``read_jsonl`` refuses files whose header major version it does not know, so
a cost model never silently fits fields that changed meaning.
"""
from __future__ import annotations

import json
import platform
from typing import Iterable

from repro.trace.tracer import SCHEMA_VERSION, Tracer


def _spans_of(trace) -> list[dict]:
    if isinstance(trace, Tracer):
        return trace.spans
    return list(trace)


def header(extra: dict | None = None) -> dict:
    h = {
        "schema": SCHEMA_VERSION,
        "kind": "repro-trace",
        "clock": "perf_counter",
        "host": platform.node(),
    }
    if extra:
        h.update(extra)
    return h


def write_jsonl(trace: Tracer | Iterable[dict], path, *,
                extra_header: dict | None = None) -> str:
    """Write header + one span per line; returns the path written."""
    spans = _spans_of(trace)
    with open(path, "w") as f:
        f.write(json.dumps(header(extra_header)) + "\n")
        for sp in spans:
            f.write(json.dumps(sp) + "\n")
    return str(path)


def read_jsonl(path) -> tuple[dict, list[dict]]:
    """Load (header, spans) back; raises ValueError on a missing header or
    an unknown schema version."""
    with open(path) as f:
        lines = [ln for ln in f if ln.strip()]
    if not lines:
        raise ValueError(f"empty trace file: {path}")
    head = json.loads(lines[0])
    if head.get("kind") != "repro-trace":
        raise ValueError(
            f"{path} is not a repro trace (missing header line; "
            f"first line: {lines[0][:80]!r})")
    if head.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"{path} has trace schema {head.get('schema')!r}; this reader "
            f"understands schema {SCHEMA_VERSION}")
    return head, [json.loads(ln) for ln in lines[1:]]


def to_chrome(trace: Tracer | Iterable[dict]) -> dict:
    """chrome://tracing / Perfetto "trace event" JSON (complete 'X' events;
    perf_counter seconds -> microsecond timestamps)."""
    events = []
    for sp in _spans_of(trace):
        events.append({
            "name": sp["name"],
            "ph": "X",
            "ts": sp["ts"] * 1e6,
            "dur": sp["dur"] * 1e6,
            "pid": 0,
            "tid": sp.get("tid", 0),
            "cat": str(sp.get("tags", {}).get("phase", "span")),
            "args": sp.get("tags", {}),
        })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": header(),
    }


def write_chrome(trace: Tracer | Iterable[dict], path) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome(trace), f)
    return str(path)
