"""Span tracing for the aggregation pipeline (DESIGN.md §13).

Hot-path API (near-zero when disabled)::

    from repro import trace

    with trace.span("bucketer.encode", bucket=i, phase="encode") as sp:
        state = encode(buf)
        sp.sync(state)      # block_until_ready -> device work lands here

Control/export API::

    trace.enable(); ... ; trace.export.write_jsonl(trace.get(), path)

CLI threading: ``trace.add_trace_args(parser)`` + ``trace.from_args(ns)``.
"""
from repro.trace import export  # noqa: F401
from repro.trace.cli import TraceSession, add_trace_args, from_args  # noqa: F401
from repro.trace.export import (  # noqa: F401
    read_jsonl, to_chrome, write_chrome, write_jsonl,
)
from repro.trace.tracer import (  # noqa: F401
    NULL_SPAN, SCHEMA_VERSION, Span, Tracer, disable, enable, enabled, get,
    span,
)
