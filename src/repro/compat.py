"""Version-compatibility shims for the jax API surface this repo targets.

The code is written against the modern jax API (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``). Older jax
releases (e.g. the 0.4.x baked into the CPU test container) expose shard_map
only under ``jax.experimental.shard_map`` with (check_rep, auto) instead of
(check_vma, axis_names), and have no AxisType at all. Importing ``make_mesh``
and ``shard_map`` from here gives every caller — src, tests, examples — one
spelling that works on both.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    AXIS_TYPE_AUTO = jax.sharding.AxisType.Auto
except AttributeError:  # older jax: meshes have no axis types
    AXIS_TYPE_AUTO = None


def axis_size(name) -> int:
    """Static size of a named (manual) mesh axis, on any jax version."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    frame = jax.core.axis_frame(name)  # old jax: returns the size itself
    return frame if isinstance(frame, int) else frame.size


def make_mesh(axis_shapes, axis_names, **kwargs):
    """jax.make_mesh with every axis Auto, on any jax version."""
    if AXIS_TYPE_AUTO is not None:
        kwargs.setdefault("axis_types", (AXIS_TYPE_AUTO,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def abstract_mesh(axis_shapes, axis_names):
    """jax.sharding.AbstractMesh with every axis Auto, on any jax version."""
    from jax.sharding import AbstractMesh

    if AXIS_TYPE_AUTO is not None:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names),
                            axis_types=(AXIS_TYPE_AUTO,) * len(axis_names))
    return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=False):
    """jax.shard_map adapter.

    ``axis_names``: the MANUAL axes (modern spelling); every other mesh axis
    stays auto. On old jax this maps to ``auto = mesh.axis_names - axis_names``
    and ``check_vma`` maps to ``check_rep``.
    """
    manual = set(axis_names) if axis_names is not None else set(mesh.axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=manual, check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset(a for a in mesh.axis_names if a not in manual)
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, auto=auto,
    )
