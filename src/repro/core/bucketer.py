"""Block-aligned gradient bucketing with overlapped streaming aggregation.

The switch in the paper aggregates a *stream* of fixed-size packets cut from
the whole gradient; SwitchML (Sapio et al., NSDI'21) shows that the
end-to-end training win comes from exactly this bucketing + streaming — not
from hundreds of tiny per-leaf collectives, each paying full encode/decode
overhead. This module is the host-side analogue for the FPISA collectives in
``core/allreduce.py``:

* ``make_plan``   — a static :class:`BucketPlan`: the gradient pytree's leaves
                    are grouped by dtype, scheduled in reverse-autograd order
                    (the leaves whose grads become ready first during backprop
                    go on the wire first), and packed into fixed-size wire
                    buckets. Every leaf starts at an offset padded up to the
                    FPISA block boundary and large leaves are split only at
                    block multiples, so **a block never spans two leaves** and
                    every block's contents are identical to the per-leaf
                    path's blocks — which is what makes every strategy
                    bit-identical to per-leaf aggregation (DESIGN.md §3).
* ``bucketed_allreduce_tree`` — packs, dispatches, and reassembles. For the
                    production ``fpisa`` strategy the dispatch is
                    **double-buffered**: the encode of bucket *i* and the
                    decode of bucket *i-1* are issued between the collective
                    launches of buckets *i-1* and *i*, so XLA's latency-hiding
                    scheduler overlaps transform work with wire time. On
                    hierarchical (pod, data) meshes, consecutive buckets are
                    striped across the in-pod shard ranks (whole-shard roll,
                    DESIGN.md §5) so the cross-pod hop of consecutive buckets
                    leaves from rotating DCI uplinks.

Bit-identity contract: for every strategy / backend / wire width, the result
equals ``jax.tree_util.tree_map(lambda g: allreduce(g, ...), tree)`` bit for
bit (enforced by tests/test_bucketer.py). When both ``bucket_bytes`` and
``chunk_elems`` are set the identity additionally requires
``chunk_elems % block == 0`` (block groupings of the two paths coincide only
at block-aligned chunk cuts; same caveat as per-leaf chunking itself).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import trace as _trace
from repro.core import agg as _agg
from repro.core.agg import AggConfig


def _ceil_to(n: int, q: int) -> int:
    return -(-n // q) * q


@dataclasses.dataclass(frozen=True)
class Segment:
    """A block-aligned slice of one leaf placed inside one bucket."""

    leaf: int    # index into the pytree's flattened leaf list
    start: int   # element offset within the flattened leaf
    size: int    # real leaf elements carried (0 = pure padding tail)
    span: int    # slots occupied in the bucket (block multiple, >= size)
    offset: int  # start offset within the bucket buffer


@dataclasses.dataclass(frozen=True)
class Bucket:
    index: int                     # dispatch order (reverse-autograd)
    group: str                     # dtype group key, e.g. "float32"
    elems: int                     # buffer length (sum of spans; block-aligned)
    segments: tuple[Segment, ...]


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    block: int
    bucket_elems: int              # target capacity per bucket, in elements
    buckets: tuple[Bucket, ...]    # in dispatch order
    passthrough: tuple[int, ...]   # leaf indices routed per-leaf (non-float /
                                   # zero-size): bucketing has nothing to gain


def make_plan(leaves: Sequence, *, block: int, bucket_bytes: int) -> BucketPlan:
    """Build the static packing plan from leaf shapes/dtypes.

    ``leaves`` may be arrays or ShapeDtypeStructs (the plan never touches
    values, so it works under ``jax.eval_shape``). Leaves are walked in
    REVERSE flatten order — gradients of the deepest layers become ready
    first during backprop, so their buckets go on the wire first — and packed
    greedily into per-dtype-group open buckets. Buckets are dispatched in the
    order they fill up.
    """
    if block <= 0:
        raise ValueError(f"block must be positive, got {block}")
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be positive, got {bucket_bytes}")

    buckets: list[Bucket] = []
    passthrough: list[int] = []
    open_buckets: dict[str, list[Segment]] = {}
    open_fill: dict[str, int] = {}
    capacity: dict[str, int] = {}

    def seal(group: str) -> None:
        segs = open_buckets.pop(group, [])
        if segs:
            buckets.append(Bucket(
                index=len(buckets), group=group,
                elems=sum(s.span for s in segs), segments=tuple(segs)))
        open_fill.pop(group, None)

    for i in reversed(range(len(leaves))):
        leaf = leaves[i]
        dtype = jnp.dtype(leaf.dtype)
        size = int(math.prod(leaf.shape)) if leaf.shape else 1
        if size == 0 or not jnp.issubdtype(dtype, jnp.floating):
            passthrough.append(i)
            continue
        group = dtype.name
        if group not in capacity:
            capacity[group] = max(block, _ceil_to(bucket_bytes // dtype.itemsize, block))
        cap = capacity[group]
        padded = _ceil_to(size, block)
        start = 0
        while start < padded:
            fill = open_fill.get(group, 0)
            take = min(padded - start, cap - fill)
            open_buckets.setdefault(group, []).append(Segment(
                leaf=i, start=start, size=max(0, min(size, start + take) - start),
                span=take, offset=fill))
            open_fill[group] = fill + take
            start += take
            if open_fill[group] >= cap:
                seal(group)
    for group in list(open_buckets):
        seal(group)

    cap_any = max(capacity.values()) if capacity else block
    return BucketPlan(block=block, bucket_elems=cap_any,
                      buckets=tuple(buckets), passthrough=tuple(passthrough))


def plan_for_config(leaves: Sequence, cfg: AggConfig) -> BucketPlan:
    return make_plan(leaves, block=cfg.block, bucket_bytes=cfg.bucket_bytes)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------


def _stage_dtype(cfg: AggConfig, group: str):
    """Wire staging dtype of a bucket buffer — the same cast the per-leaf
    path applies to each leaf before aggregating (cast is elementwise, so
    cast-then-concat == concat-then-cast). Declared per strategy on its
    registry spec (``StrategySpec.stage_dtype``); float32 by default."""
    spec = _agg.get_strategy(cfg.strategy)
    if spec.stage_dtype is not None:
        return spec.stage_dtype(cfg, group)
    return jnp.float32  # switchml / fpisa_seq / switch_emu


def pack_bucket(bucket: Bucket, flat_leaves, stage_dtype) -> jax.Array:
    """Assemble one bucket buffer from (already flattened) leaves."""
    parts = []
    for s in bucket.segments:
        piece = lax.slice(flat_leaves[s.leaf], (s.start,), (s.start + s.size,)) \
            if s.size else None
        if piece is not None:
            piece = piece.astype(stage_dtype)
            if s.span > s.size:
                piece = jnp.pad(piece, (0, s.span - s.size))
        else:
            piece = jnp.zeros((s.span,), stage_dtype)
        parts.append(piece)
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unpack_bucket(bucket: Bucket, out: jax.Array, pieces: dict) -> None:
    """Scatter an aggregated bucket buffer back into per-leaf piece lists."""
    for s in bucket.segments:
        if s.size:
            pieces[s.leaf].append(
                (s.start, lax.slice(out, (s.offset,), (s.offset + s.size,))))


# ---------------------------------------------------------------------------
# per-bucket dispatch: split-phase pipeline (registry hooks) / generic call
# ---------------------------------------------------------------------------


def _stream_buckets(plan: BucketPlan, flat_leaves: dict, cfg: AggConfig,
                    pack_fn, phases_for, generic_fn) -> dict:
    """Double-buffered dispatch shared by the per-leaf and stacked tree
    entries: for each bucket the trace issues
        encode(i) -> [finish(i-1)] -> collective(i)
    so the decode of the in-flight bucket and the encode of the next one sit
    between consecutive collective launches — the transform work of bucket i
    overlaps the wire time of bucket i-1 under any latency-hiding scheduler.

    ``pack_fn(bucket, stage_dtype)`` assembles the wire buffer;
    ``phases_for(bucket)`` returns (encode, collect, finish) for split-phase
    pipelined strategies or None to dispatch through the one-shot
    ``generic_fn(buffer)`` with the same interleaving. Returns the
    {leaf index: [(start, aggregated piece), ...]} map."""
    pieces: dict[int, list] = {i: [] for i in flat_leaves}
    inflight = None  # (bucket, state, finish_fn or None)

    def land(entry):
        bucket, state, finish = entry
        with _trace.span("bucketer.finish", phase="finish",
                         bucket=bucket.index, elems=bucket.elems,
                         group=bucket.group) as sp:
            out = finish(state) if finish is not None else state
            sp.sync(out)
        unpack_bucket(bucket, out, pieces)

    for bucket in plan.buckets:
        phases = phases_for(bucket)
        if phases is not None:
            encode, collect, finish = phases
            with _trace.span("bucketer.encode", phase="encode",
                             bucket=bucket.index, elems=bucket.elems,
                             group=bucket.group) as sp:
                buf = pack_fn(bucket, _stage_dtype(cfg, bucket.group))
                state = encode(buf)
                sp.sync(state)
            if inflight is not None:
                land(inflight)
            with _trace.span("bucketer.collective", phase="collective",
                             bucket=bucket.index, elems=bucket.elems,
                             group=bucket.group) as sp:
                collected = collect(state)
                sp.sync(collected)
            inflight = (bucket, collected, finish)
        else:
            with _trace.span("bucketer.dispatch", phase="dispatch",
                             bucket=bucket.index, elems=bucket.elems,
                             group=bucket.group) as sp:
                buf = pack_fn(bucket, _stage_dtype(cfg, bucket.group))
                out = generic_fn(buf)
                sp.sync(out)
            if inflight is not None:
                land(inflight)
            inflight = (bucket, out, None)
    if inflight is not None:
        land(inflight)
    return pieces


def _reassemble(leaves, treedef, results: dict, pieces: dict, shape_of):
    for i, leaf in enumerate(leaves):
        if i in results:
            continue
        ps = sorted(pieces[i], key=lambda t: t[0])
        flat = jnp.concatenate([p for _, p in ps]) if len(ps) > 1 else ps[0][1]
        results[i] = flat.reshape(shape_of(leaf)).astype(leaf.dtype)
    return jax.tree_util.tree_unflatten(
        treedef, [results[i] for i in range(len(leaves))])


def bucketed_allreduce_tree(tree, axis_names: Sequence[str], cfg: AggConfig):
    """Aggregate a gradient pytree through fixed-size streamed wire buckets
    with double-buffered dispatch (``_stream_buckets``). Strategies exposing
    split-phase hooks on their registry spec (``flat_phases``/``hier_phases``)
    pipeline encode/collective/decode; everything else (and chunked dispatch)
    goes through the one-shot facade path with the same interleaving."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree
    axes = tuple(axis_names)
    inner = dataclasses.replace(cfg, bucket_bytes=0)
    plan = plan_for_config(leaves, cfg)

    results: dict[int, jax.Array] = {}
    for i in plan.passthrough:
        results[i] = _agg._dispatch(leaves[i], axes, inner)

    planned = {s.leaf for b in plan.buckets for s in b.segments}
    flat_leaves = {i: jnp.ravel(leaves[i]) for i in planned}

    spec = _agg.get_strategy(cfg.strategy)
    hier = len(axes) == 2 and spec.hier_phases is not None
    pipelined = not cfg.chunk_elems and (
        spec.hier_phases is not None if hier else spec.flat_phases is not None)
    backend = _agg.resolve_backend(cfg.backend)
    flat_phases = None

    def phases_for(bucket):
        nonlocal flat_phases
        if not pipelined:
            return None
        if hier:
            return spec.hier_phases(axes[1], axes[0], cfg, backend,
                                    stripe=bucket.index)
        if flat_phases is None:
            flat_phases = spec.flat_phases(axes, cfg, backend)
        return flat_phases

    pieces = _stream_buckets(
        plan, flat_leaves, cfg,
        lambda bucket, dt: pack_bucket(bucket, flat_leaves, dt),
        phases_for,
        lambda buf: _agg._dispatch(buf, axes, inner))
    return _reassemble(leaves, treedef, results, pieces, lambda l: l.shape)


# ---------------------------------------------------------------------------
# stacked (logical-worker) bucketed dispatch — elastic recovery (DESIGN.md §8)
# ---------------------------------------------------------------------------


def bucketed_stacked_allreduce_tree(tree, axis_names: Sequence[str],
                                    cfg: AggConfig):
    """``bucketed_allreduce_tree`` for per-logical-worker gradient stacks:
    every leaf carries a leading worker axis of size k and the reduction runs
    over that axis plus the mesh axes (core/allreduce.py stacked section).

    The plan is built from the PER-WORKER leaf shapes (leading axis dropped),
    so the wire layout — block alignment, bucket cuts, dispatch order — is
    identical to the unstacked plan of the same pytree, and identical across
    meshes: re-tracing on a survivor mesh after a failure re-plans for the
    new k without changing a single block boundary. Packing vmaps the same
    ``pack_bucket`` over the worker axis; aggregated buckets come back
    reduced (1-D) and unpack through the unchanged ``unpack_bucket``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree
    axes = tuple(axis_names)
    k = leaves[0].shape[0]
    inner = dataclasses.replace(cfg, bucket_bytes=0)
    per_worker = [jax.ShapeDtypeStruct(l.shape[1:], l.dtype) for l in leaves]
    plan = plan_for_config(per_worker, cfg)

    results: dict[int, jax.Array] = {}
    for i in plan.passthrough:
        results[i] = _agg._dispatch_stacked(leaves[i], axes, inner)

    planned = {s.leaf for b in plan.buckets for s in b.segments}
    flat_leaves = {i: leaves[i].reshape(k, -1) for i in planned}

    spec = _agg.get_strategy(cfg.strategy)
    backend = _agg.resolve_backend(cfg.backend)
    phases = None

    def phases_for(bucket):
        nonlocal phases
        if spec.stacked_phases is None:
            return None
        if phases is None:
            phases = spec.stacked_phases(axes, cfg, backend, k)
        return phases

    pieces = _stream_buckets(
        plan, flat_leaves, cfg,
        lambda bucket, dt: jax.vmap(
            lambda fl: pack_bucket(bucket, fl, dt))(flat_leaves),
        phases_for,
        lambda buf: _agg._dispatch_stacked(buf, axes, inner))
    return _reassemble(leaves, treedef, results, pieces, lambda l: l.shape[1:])
