"""Block-aligned gradient bucketing with overlapped streaming aggregation.

The switch in the paper aggregates a *stream* of fixed-size packets cut from
the whole gradient; SwitchML (Sapio et al., NSDI'21) shows that the
end-to-end training win comes from exactly this bucketing + streaming — not
from hundreds of tiny per-leaf collectives, each paying full encode/decode
overhead. This module is the host-side analogue for the FPISA collectives in
``core/allreduce.py``:

* ``make_plan``   — a static :class:`BucketPlan`: the gradient pytree's leaves
                    are grouped by dtype, scheduled in reverse-autograd order
                    (the leaves whose grads become ready first during backprop
                    go on the wire first), and packed into fixed-size wire
                    buckets. Every leaf starts at an offset padded up to the
                    FPISA block boundary and large leaves are split only at
                    block multiples, so **a block never spans two leaves** and
                    every block's contents are identical to the per-leaf
                    path's blocks — which is what makes every strategy
                    bit-identical to per-leaf aggregation (DESIGN.md §3).
* ``bucketed_allreduce_tree`` — packs, dispatches, and reassembles. For the
                    production ``fpisa`` strategy the dispatch is
                    **double-buffered**: the encode of bucket *i* and the
                    decode of bucket *i-1* are issued between the collective
                    launches of buckets *i-1* and *i*, so XLA's latency-hiding
                    scheduler overlaps transform work with wire time. On
                    hierarchical (pod, data) meshes, consecutive buckets are
                    striped across the in-pod shard ranks (whole-shard roll,
                    DESIGN.md §5) so the cross-pod hop of consecutive buckets
                    leaves from rotating DCI uplinks.

Bit-identity contract: for every strategy / backend / wire width, the result
equals ``jax.tree_util.tree_map(lambda g: allreduce(g, ...), tree)`` bit for
bit (enforced by tests/test_bucketer.py). When both ``bucket_bytes`` and
``chunk_elems`` are set the identity additionally requires
``chunk_elems % block == 0`` (block groupings of the two paths coincide only
at block-aligned chunk cuts; same caveat as per-leaf chunking itself).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core import allreduce as ar


def _ceil_to(n: int, q: int) -> int:
    return -(-n // q) * q


@dataclasses.dataclass(frozen=True)
class Segment:
    """A block-aligned slice of one leaf placed inside one bucket."""

    leaf: int    # index into the pytree's flattened leaf list
    start: int   # element offset within the flattened leaf
    size: int    # real leaf elements carried (0 = pure padding tail)
    span: int    # slots occupied in the bucket (block multiple, >= size)
    offset: int  # start offset within the bucket buffer


@dataclasses.dataclass(frozen=True)
class Bucket:
    index: int                     # dispatch order (reverse-autograd)
    group: str                     # dtype group key, e.g. "float32"
    elems: int                     # buffer length (sum of spans; block-aligned)
    segments: tuple[Segment, ...]


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    block: int
    bucket_elems: int              # target capacity per bucket, in elements
    buckets: tuple[Bucket, ...]    # in dispatch order
    passthrough: tuple[int, ...]   # leaf indices routed per-leaf (non-float /
                                   # zero-size): bucketing has nothing to gain


def make_plan(leaves: Sequence, *, block: int, bucket_bytes: int) -> BucketPlan:
    """Build the static packing plan from leaf shapes/dtypes.

    ``leaves`` may be arrays or ShapeDtypeStructs (the plan never touches
    values, so it works under ``jax.eval_shape``). Leaves are walked in
    REVERSE flatten order — gradients of the deepest layers become ready
    first during backprop, so their buckets go on the wire first — and packed
    greedily into per-dtype-group open buckets. Buckets are dispatched in the
    order they fill up.
    """
    if block <= 0:
        raise ValueError(f"block must be positive, got {block}")
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be positive, got {bucket_bytes}")

    buckets: list[Bucket] = []
    passthrough: list[int] = []
    open_buckets: dict[str, list[Segment]] = {}
    open_fill: dict[str, int] = {}
    capacity: dict[str, int] = {}

    def seal(group: str) -> None:
        segs = open_buckets.pop(group, [])
        if segs:
            buckets.append(Bucket(
                index=len(buckets), group=group,
                elems=sum(s.span for s in segs), segments=tuple(segs)))
        open_fill.pop(group, None)

    for i in reversed(range(len(leaves))):
        leaf = leaves[i]
        dtype = jnp.dtype(leaf.dtype)
        size = int(math.prod(leaf.shape)) if leaf.shape else 1
        if size == 0 or not jnp.issubdtype(dtype, jnp.floating):
            passthrough.append(i)
            continue
        group = dtype.name
        if group not in capacity:
            capacity[group] = max(block, _ceil_to(bucket_bytes // dtype.itemsize, block))
        cap = capacity[group]
        padded = _ceil_to(size, block)
        start = 0
        while start < padded:
            fill = open_fill.get(group, 0)
            take = min(padded - start, cap - fill)
            open_buckets.setdefault(group, []).append(Segment(
                leaf=i, start=start, size=max(0, min(size, start + take) - start),
                span=take, offset=fill))
            open_fill[group] = fill + take
            start += take
            if open_fill[group] >= cap:
                seal(group)
    for group in list(open_buckets):
        seal(group)

    cap_any = max(capacity.values()) if capacity else block
    return BucketPlan(block=block, bucket_elems=cap_any,
                      buckets=tuple(buckets), passthrough=tuple(passthrough))


def plan_for_config(leaves: Sequence, cfg: ar.AggConfig) -> BucketPlan:
    return make_plan(leaves, block=cfg.block, bucket_bytes=cfg.bucket_bytes)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------


def _stage_dtype(cfg: ar.AggConfig, group: str):
    """Wire staging dtype of a bucket buffer — the same cast the per-leaf
    path applies to each leaf before aggregating (cast is elementwise, so
    cast-then-concat == concat-then-cast)."""
    if cfg.strategy == "native":
        return jnp.dtype(group)  # native psums in the leaf dtype
    if cfg.strategy == "fpisa":
        return ar._PACKED[cfg.fmt_name]
    return jnp.float32  # switchml / fpisa_seq / switch_emu


def pack_bucket(bucket: Bucket, flat_leaves, stage_dtype) -> jax.Array:
    """Assemble one bucket buffer from (already flattened) leaves."""
    parts = []
    for s in bucket.segments:
        piece = lax.slice(flat_leaves[s.leaf], (s.start,), (s.start + s.size,)) \
            if s.size else None
        if piece is not None:
            piece = piece.astype(stage_dtype)
            if s.span > s.size:
                piece = jnp.pad(piece, (0, s.span - s.size))
        else:
            piece = jnp.zeros((s.span,), stage_dtype)
        parts.append(piece)
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unpack_bucket(bucket: Bucket, out: jax.Array, pieces: dict) -> None:
    """Scatter an aggregated bucket buffer back into per-leaf piece lists."""
    for s in bucket.segments:
        if s.size:
            pieces[s.leaf].append(
                (s.start, lax.slice(out, (s.offset,), (s.offset + s.size,))))


# ---------------------------------------------------------------------------
# per-bucket dispatch: split-phase fpisa pipeline / generic strategy call
# ---------------------------------------------------------------------------


def _fpisa_flat_phases(axes, cfg: ar.AggConfig, backend: str):
    """(encode, collect, finish) for the flat single-level fpisa path —
    mirrors ``fpisa_allreduce`` exactly (bucket buffers are already block
    multiples, so its pad step is a no-op here)."""
    w = ar._axis_size(axes)
    shift = ar._wire_shift(cfg.fmt, w, cfg.wire_bits)

    def encode(flat):
        man, bmax = ar._encode_align(flat, axes, shift, cfg, backend)
        if cfg.wire_bits == 16:
            man = man.astype(jnp.int16)
        elif cfg.wire_bits == 8:
            man = man.astype(jnp.int8)
        return man, bmax

    def collect(state):
        man, bmax = state
        return lax.psum(man, axes), bmax

    def finish(state):
        man_sum, bmax = state
        return ar._decode(man_sum, bmax, shift, cfg, backend)

    return encode, collect, finish


def _fpisa_hier_phases(data_axis, pod_axis, cfg: ar.AggConfig, backend: str,
                       stripe: int):
    """(encode, collect, finish) for the hierarchical fpisa path.

    ``stripe`` rotates the in-pod reduce-scatter shard assignment of this
    bucket by whole shards (a block-multiple roll): bucket i's cross-pod hop
    and delayed renorm for any given gradient range land on data-rank
    (rank + i) % w_data, striping consecutive buckets' DCI traffic across the
    pod axis's uplinks. Rolling by whole shards keeps every block's contents
    intact, so the result is bit-identical to the unstriped path.
    """
    w_data = compat.axis_size(data_axis)
    w_pod = compat.axis_size(pod_axis)
    shift = ar._wire_shift(cfg.fmt, w_data * w_pod, cfg.wire_bits)
    quantum = cfg.block * w_data

    def encode(flat):
        pad = (-flat.shape[0]) % quantum
        if pad:
            flat = jnp.pad(flat, (0, pad))
        roll = (stripe % w_data) * (flat.shape[0] // w_data)
        if roll:
            flat = jnp.roll(flat, -roll)
        man, bmax = ar._encode_align(
            flat, (data_axis, pod_axis), shift, cfg, backend)
        return man, bmax, pad, roll

    def collect(state):
        man, bmax, pad, roll = state
        man_shard, pod_shift = ar._hier_collect(man, data_axis, pod_axis, cfg, shift)
        return man_shard, bmax, pod_shift, pad, roll

    def finish(state):
        man_shard, bmax, pod_shift, pad, roll = state
        out = ar._hier_finish(man_shard, bmax, shift, pod_shift, data_axis,
                              cfg, backend)
        if roll:
            out = jnp.roll(out, roll)
        if pad:
            out = out[:out.shape[0] - pad]
        return out

    return encode, collect, finish


def _stream_buckets(plan: BucketPlan, flat_leaves: dict, cfg: ar.AggConfig,
                    pack_fn, phases_for, generic_fn) -> dict:
    """Double-buffered dispatch shared by the per-leaf and stacked tree
    entries: for each bucket the trace issues
        encode(i) -> [finish(i-1)] -> collective(i)
    so the decode of the in-flight bucket and the encode of the next one sit
    between consecutive collective launches — the transform work of bucket i
    overlaps the wire time of bucket i-1 under any latency-hiding scheduler.

    ``pack_fn(bucket, stage_dtype)`` assembles the wire buffer;
    ``phases_for(bucket)`` returns (encode, collect, finish) for split-phase
    pipelined strategies or None to dispatch through the one-shot
    ``generic_fn(buffer)`` with the same interleaving. Returns the
    {leaf index: [(start, aggregated piece), ...]} map."""
    pieces: dict[int, list] = {i: [] for i in flat_leaves}
    inflight = None  # (bucket, state, finish_fn or None)

    def land(entry):
        bucket, state, finish = entry
        out = finish(state) if finish is not None else state
        unpack_bucket(bucket, out, pieces)

    for bucket in plan.buckets:
        buf = pack_fn(bucket, _stage_dtype(cfg, bucket.group))
        phases = phases_for(bucket)
        if phases is not None:
            encode, collect, finish = phases
            state = encode(buf)
            if inflight is not None:
                land(inflight)
            inflight = (bucket, collect(state), finish)
        else:
            out = generic_fn(buf)
            if inflight is not None:
                land(inflight)
            inflight = (bucket, out, None)
    if inflight is not None:
        land(inflight)
    return pieces


def _reassemble(leaves, treedef, results: dict, pieces: dict, shape_of):
    for i, leaf in enumerate(leaves):
        if i in results:
            continue
        ps = sorted(pieces[i], key=lambda t: t[0])
        flat = jnp.concatenate([p for _, p in ps]) if len(ps) > 1 else ps[0][1]
        results[i] = flat.reshape(shape_of(leaf)).astype(leaf.dtype)
    return jax.tree_util.tree_unflatten(
        treedef, [results[i] for i in range(len(leaves))])


def bucketed_allreduce_tree(tree, axis_names: Sequence[str], cfg: ar.AggConfig):
    """Aggregate a gradient pytree through fixed-size streamed wire buckets
    with double-buffered dispatch (``_stream_buckets``); non-pipelined
    strategies (and chunked fpisa) go through the one-shot ``allreduce``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree
    axes = tuple(axis_names)
    inner = dataclasses.replace(cfg, bucket_bytes=0)
    plan = plan_for_config(leaves, cfg)

    results: dict[int, jax.Array] = {}
    for i in plan.passthrough:
        results[i] = ar.allreduce(leaves[i], axes, inner)

    planned = {s.leaf for b in plan.buckets for s in b.segments}
    flat_leaves = {i: jnp.ravel(leaves[i]) for i in planned}

    hier = cfg.strategy == "fpisa" and len(axes) == 2
    pipelined = cfg.strategy == "fpisa" and not cfg.chunk_elems
    backend = ar.resolve_backend(cfg.backend)
    flat_phases = None

    def phases_for(bucket):
        nonlocal flat_phases
        if not pipelined:
            return None
        if hier:
            return _fpisa_hier_phases(axes[1], axes[0], cfg, backend,
                                      stripe=bucket.index)
        if flat_phases is None:
            flat_phases = _fpisa_flat_phases(axes, cfg, backend)
        return flat_phases

    pieces = _stream_buckets(
        plan, flat_leaves, cfg,
        lambda bucket, dt: pack_bucket(bucket, flat_leaves, dt),
        phases_for,
        lambda buf: ar.allreduce(buf, axes, inner))
    return _reassemble(leaves, treedef, results, pieces, lambda l: l.shape)


# ---------------------------------------------------------------------------
# stacked (logical-worker) bucketed dispatch — elastic recovery (DESIGN.md §8)
# ---------------------------------------------------------------------------


def _fpisa_stacked_phases(axes, cfg: ar.AggConfig, backend: str, k: int):
    """(encode, collect, finish) for the stacked flat fpisa path — mirrors
    ``stacked_fpisa_allreduce``: per-worker encode + exact local int fold
    before the wire, W-derived shift, one delayed renorm after the psum."""
    w = k * ar._axis_size(axes)
    shift = ar._wire_shift(cfg.fmt, w, cfg.wire_bits)

    def encode(buf):  # (k, elems) packed FP
        man, bmax = ar._encode_align_stacked(buf, axes, shift, cfg, backend)
        man = ar._wire_cast(man, cfg.wire_bits)
        local = ar._wire_cast(jnp.sum(man.astype(jnp.int32), axis=0),
                              cfg.wire_bits)
        return local, bmax

    def collect(state):
        man, bmax = state
        return lax.psum(man, axes), bmax

    def finish(state):
        man_sum, bmax = state
        return ar._decode(man_sum, bmax, shift, cfg, backend)

    return encode, collect, finish


def bucketed_stacked_allreduce_tree(tree, axis_names: Sequence[str],
                                    cfg: ar.AggConfig):
    """``bucketed_allreduce_tree`` for per-logical-worker gradient stacks:
    every leaf carries a leading worker axis of size k and the reduction runs
    over that axis plus the mesh axes (core/allreduce.py stacked section).

    The plan is built from the PER-WORKER leaf shapes (leading axis dropped),
    so the wire layout — block alignment, bucket cuts, dispatch order — is
    identical to the unstacked plan of the same pytree, and identical across
    meshes: re-tracing on a survivor mesh after a failure re-plans for the
    new k without changing a single block boundary. Packing vmaps the same
    ``pack_bucket`` over the worker axis; aggregated buckets come back
    reduced (1-D) and unpack through the unchanged ``unpack_bucket``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree
    axes = tuple(axis_names)
    k = leaves[0].shape[0]
    inner = dataclasses.replace(cfg, bucket_bytes=0)
    per_worker = [jax.ShapeDtypeStruct(l.shape[1:], l.dtype) for l in leaves]
    plan = plan_for_config(per_worker, cfg)

    results: dict[int, jax.Array] = {}
    for i in plan.passthrough:
        results[i] = ar.stacked_allreduce(leaves[i], axes, inner)

    planned = {s.leaf for b in plan.buckets for s in b.segments}
    flat_leaves = {i: leaves[i].reshape(k, -1) for i in planned}

    pipelined = cfg.strategy == "fpisa"
    backend = ar.resolve_backend(cfg.backend)
    phases = None

    def phases_for(bucket):
        nonlocal phases
        if not pipelined:
            return None
        if phases is None:
            phases = _fpisa_stacked_phases(axes, cfg, backend, k)
        return phases

    pieces = _stream_buckets(
        plan, flat_leaves, cfg,
        lambda bucket, dt: jax.vmap(
            lambda fl: pack_bucket(bucket, fl, dt))(flat_leaves),
        phases_for,
        lambda buf: ar.stacked_allreduce(buf, axes, inner))
    return _reassemble(leaves, treedef, results, pieces, lambda l: l.shape[1:])
