"""Functional PISA switch emulator for FPISA aggregation.

Models the switch-resident part of a SwitchML/FPISA deployment faithfully
enough to test the *protocol* properties the paper relies on:

* a pool of aggregation **slots**, each holding ``elems_per_packet`` FPISA
  accumulator registers (exponent plane + signed mantissa plane) plus a
  per-slot worker **bitmap** (idempotence under retransmission) and a
  completion counter;
* streaming chunked aggregation: each worker sends chunk ``c`` to slot
  ``c % num_slots``; the slot broadcasts the aggregate when all workers have
  contributed, then is reused for chunk ``c + num_slots`` (SwitchML's
  streaming window);
* packet loss + timeout retransmission: duplicate packets are ignored via the
  bitmap — the aggregation is **exactly-once** per (worker, chunk) even under
  an unreliable fabric. This is the fault-tolerance mechanism of the paper's
  deployment scenario, reproduced and tested.

The emulator is a pure-Python/numpy state machine (control plane) driving
jnp FPISA arithmetic (data plane); it is used by tests and accuracy
benchmarks, not by the training hot path.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core import fpisa


@dataclasses.dataclass
class SwitchConfig:
    num_workers: int
    num_slots: int = 8
    elems_per_packet: int = 256  # paper: largest SwitchML packet
    fmt_name: str = "fp32"
    variant: str = "fpisa_a"  # fpisa_a | full

    @property
    def fmt(self):
        return fpisa.FORMATS[self.fmt_name]


@dataclasses.dataclass
class Packet:
    worker: int
    chunk: int
    payload: np.ndarray  # float32 (elems_per_packet,)


@dataclasses.dataclass
class ResultPacket:
    chunk: int
    payload: np.ndarray


class FpisaSwitch:
    """One emulated ingress pipeline worth of FPISA aggregation slots."""

    def __init__(self, cfg: SwitchConfig):
        self.cfg = cfg
        # SwitchML-style double pool: chunk c lives in slot c % (2*num_slots),
        # so a completed slot can keep serving retransmissions for a full
        # window after completion before being recycled.
        n, e = 2 * cfg.num_slots, cfg.elems_per_packet
        self.num_physical_slots = n
        self._exp = np.zeros((n, e), np.int32)
        self._man = np.zeros((n, e), np.int32)
        self._bitmap = np.zeros((n,), np.int64)  # bit w set => worker w seen
        self._slot_chunk = np.full((n,), -1, np.int64)  # chunk owning the slot
        self._result = [None] * n  # cached broadcast payload once complete
        self.stats = {"packets": 0, "duplicates": 0, "overwrite": 0, "overflow": 0}

    def _add(self, slot: int, payload: np.ndarray) -> None:
        inp = fpisa.encode(jnp.asarray(payload, jnp.float32), self.cfg.fmt)
        acc = fpisa.Planes(jnp.asarray(self._exp[slot]), jnp.asarray(self._man[slot]))
        add = fpisa.fpisa_a_add if self.cfg.variant == "fpisa_a" else fpisa.fpisa_add_full
        new, st = add(acc, inp, self.cfg.fmt)
        self._exp[slot] = np.asarray(new.exp)
        self._man[slot] = np.asarray(new.man)
        self.stats["overwrite"] += int(np.sum(np.asarray(st.overwrite)))
        self.stats["overflow"] += int(np.sum(np.asarray(st.overflow)))

    def ingest(self, pkt: Packet) -> ResultPacket | None:
        """Process one packet; returns the broadcast result when a slot fills,
        or re-serves the cached result for duplicate packets of a completed
        chunk (idempotent exactly-once aggregation under retransmission)."""
        cfg = self.cfg
        slot = pkt.chunk % self.num_physical_slots
        if self._slot_chunk[slot] != pkt.chunk:
            if self._slot_chunk[slot] > pkt.chunk:
                # retransmission for a chunk whose slot was already recycled —
                # cannot happen under the window discipline (tested); drop.
                self.stats["duplicates"] += 1
                return None
            # first packet of a new chunk claims the (recycled) slot
            self._slot_chunk[slot] = pkt.chunk
            self._bitmap[slot] = 0
            self._exp[slot] = 0
            self._man[slot] = 0
            self._result[slot] = None
        bit = np.int64(1) << np.int64(pkt.worker)
        full = (np.int64(1) << np.int64(cfg.num_workers)) - 1
        if self._bitmap[slot] & bit:
            self.stats["duplicates"] += 1  # idempotent: do NOT re-add
            if self._result[slot] is not None:
                return ResultPacket(chunk=pkt.chunk, payload=self._result[slot])
            return None
        self._bitmap[slot] |= bit
        self.stats["packets"] += 1
        self._add(slot, pkt.payload)
        if self._bitmap[slot] == full:
            planes = fpisa.Planes(jnp.asarray(self._exp[slot]), jnp.asarray(self._man[slot]))
            out = np.asarray(fpisa.renormalize(planes, cfg.fmt))
            self._result[slot] = out
            return ResultPacket(chunk=pkt.chunk, payload=out)
        return None


def run_aggregation(
    switch: FpisaSwitch,
    worker_vectors: np.ndarray,
    drop_prob: float = 0.0,
    seed: int = 0,
    max_rounds: int = 10_000,
) -> np.ndarray:
    """Drive a full all-reduce of ``worker_vectors`` (W, N) through the switch.

    Simulates an unreliable fabric in BOTH directions: each request and each
    per-worker result delivery is dropped i.i.d. with ``drop_prob``; workers
    retransmit un-acked chunks each round (timeout) and the switch re-serves
    completed slots idempotently. A worker may only send chunk ``c`` after it
    has received the result of chunk ``c - num_slots`` (SwitchML's
    self-clocked streaming window — this is what makes slot recycling safe).
    Returns the aggregated (N,) vector.
    """
    cfg = switch.cfg
    w, n = worker_vectors.shape
    assert w == cfg.num_workers
    e = cfg.elems_per_packet
    pad = (-n) % e
    vecs = np.pad(worker_vectors, ((0, 0), (0, pad))).astype(np.float32)
    nchunks = vecs.shape[1] // e
    rng = np.random.default_rng(seed)

    out = np.zeros_like(vecs[0])
    have_result = np.zeros((w, nchunks), bool)  # per-worker result delivery

    def eligible(worker: int, c: int) -> bool:
        if c >= nchunks or have_result[worker, c]:
            return False
        prev = c - cfg.num_slots
        return prev < 0 or have_result[worker, prev]

    for _ in range(max_rounds):
        if have_result.all():
            break
        for worker in range(w):
            for c in range(nchunks):
                if not eligible(worker, c):
                    continue
                if rng.random() < drop_prob:
                    continue  # request lost; retried next round
                res = switch.ingest(Packet(worker, c, vecs[worker, c * e:(c + 1) * e]))
                if res is not None:
                    out[c * e:(c + 1) * e] = res.payload
                    # broadcast: each worker's copy may be dropped independently
                    for wk in range(w):
                        if not have_result[wk, c] and rng.random() >= drop_prob:
                            have_result[wk, c] = True
    if not have_result.all():
        raise RuntimeError("aggregation did not complete within max_rounds")
    return out[:n]
