"""Legacy per-packet PISA switch emulator — now a thin compatibility shim.

The protocol semantics documented here (slot pool, worker bitmap
idempotence, SwitchML double-pool window recycling, exactly-once aggregation
under an unreliable fabric) are implemented once, vectorized and
jit-compiled, in ``repro/switchsim/dataplane.py``. ``FpisaSwitch`` keeps the
original one-packet-at-a-time API by driving a single-pipeline
``BatchedDataplane`` with batch size 1; ``run_aggregation`` keeps the
original *immediate-eligibility* driver loop (a worker's send can unblock a
later worker within the same round) that the legacy tests pin.

Use ``repro.switchsim`` directly for anything throughput-sensitive: its
``run_aggregation`` submits every eligible packet of a round as one batch
(~100x the packet rate of this shim — measured in
``benchmarks/fig10_goodput.py``) and models multiple ingress pipelines.

Stats note: retransmissions that arrive after their slot was recycled for a
newer chunk are counted under ``stats["stale"]``; ``stats["duplicates"]``
now counts only true bitmap hits (same (worker, chunk) seen twice). The
pre-refactor emulator conflated the two under ``duplicates``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import switchsim
from repro.core import fpisa


@dataclasses.dataclass
class SwitchConfig:
    num_workers: int
    num_slots: int = 8
    elems_per_packet: int = 256  # paper: largest SwitchML packet
    fmt_name: str = "fp32"
    variant: str = "fpisa_a"  # fpisa_a | full

    @property
    def fmt(self):
        return fpisa.FORMATS[self.fmt_name]


@dataclasses.dataclass
class Packet:
    worker: int
    chunk: int
    payload: np.ndarray  # float32 (elems_per_packet,)


@dataclasses.dataclass
class ResultPacket:
    chunk: int
    payload: np.ndarray


class FpisaSwitch:
    """One emulated ingress pipeline worth of FPISA aggregation slots
    (per-packet view over a 1-pipeline batched dataplane)."""

    def __init__(self, cfg: SwitchConfig):
        self.cfg = cfg
        self._dp = switchsim.BatchedDataplane(switchsim.DataplaneConfig(
            num_workers=cfg.num_workers,
            num_slots=cfg.num_slots,
            elems_per_packet=cfg.elems_per_packet,
            fmt_name=cfg.fmt_name,
            variant=cfg.variant,
            num_pipelines=1,
            rounds_per_call=1,  # one packet per dispatch: rank is always 0
        ))
        self.num_physical_slots = self._dp.cfg.physical_slots_per_pipeline

    @property
    def stats(self) -> dict:
        s = self._dp.stats
        return {k: s[k] for k in switchsim.COUNTERS}

    @property
    def job_stats(self) -> list:
        """Per-tenant counters of the underlying dataplane."""
        return self._dp.job_stats

    def reclaim_worker(self, worker: int, job: int = 0):
        """Dead-worker reclamation (control plane): free the worker's parked
        in-flight slots owned by ``job`` and waive its bitmap bit for future
        completions — see repro/switchsim/dataplane.py \"Worker-failure
        reclamation\"."""
        self._dp.reclaim_worker(worker, job)

    def ingest(self, pkt: Packet, job: int = 0, now: int = 0) -> ResultPacket | None:
        """Process one packet; returns the broadcast result when a slot fills,
        or re-serves the cached result for duplicate packets of a completed
        chunk (idempotent exactly-once aggregation under retransmission).
        ``job``/``now`` tag the packet's tenant and the driver's staleness
        clock on a multi-tenant switch (defaults preserve the single-tenant
        behavior bit for bit)."""
        ready, results, _ = self._dp.ingest_batch(
            [pkt.worker], [pkt.chunk], pkt.payload[None, :],
            jobs=[job], now=now)
        if ready[0]:
            return ResultPacket(chunk=pkt.chunk, payload=results[0])
        return None


def run_aggregation(
    switch: FpisaSwitch,
    worker_vectors: np.ndarray,
    drop_prob: float = 0.0,
    seed: int = 0,
    max_rounds: int = 10_000,
) -> np.ndarray:
    """Drive a full all-reduce of ``worker_vectors`` (W, N) through the switch.

    Simulates an unreliable fabric in BOTH directions: each request and each
    per-worker result delivery is dropped i.i.d. with ``drop_prob``; workers
    retransmit un-acked chunks each round (timeout) and the switch re-serves
    completed slots idempotently. A worker may only send chunk ``c`` after it
    has received the result of chunk ``c - num_slots`` (SwitchML's
    self-clocked streaming window — this is what makes slot recycling safe).
    Returns the aggregated (N,) vector.

    This is the legacy immediate-eligibility schedule (eligibility re-checked
    per packet, so completions unblock later sends within the same round).
    ``repro.switchsim.run_aggregation`` is the batched round-synchronous
    driver; it accepts this class too, for per-packet/batched parity runs.
    """
    cfg = switch.cfg
    w, n = worker_vectors.shape
    assert w == cfg.num_workers
    e = cfg.elems_per_packet
    pad = (-n) % e
    vecs = np.pad(worker_vectors, ((0, 0), (0, pad))).astype(np.float32)
    nchunks = vecs.shape[1] // e
    rng = np.random.default_rng(seed)

    out = np.zeros_like(vecs[0])
    have_result = np.zeros((w, nchunks), bool)  # per-worker result delivery

    def eligible(worker: int, c: int) -> bool:
        if c >= nchunks or have_result[worker, c]:
            return False
        prev = c - cfg.num_slots
        return prev < 0 or have_result[worker, prev]

    for _ in range(max_rounds):
        if have_result.all():
            break
        for worker in range(w):
            for c in range(nchunks):
                if not eligible(worker, c):
                    continue
                if rng.random() < drop_prob:
                    continue  # request lost; retried next round
                res = switch.ingest(Packet(worker, c, vecs[worker, c * e:(c + 1) * e]))
                if res is not None:
                    out[c * e:(c + 1) * e] = res.payload
                    # broadcast: each worker's copy may be dropped independently
                    for wk in range(w):
                        if not have_result[wk, c] and rng.random() >= drop_prob:
                            have_result[wk, c] = True
    if not have_result.all():
        raise RuntimeError("aggregation did not complete within max_rounds")
    return out[:n]
