"""Gradient-aggregation strategies (the paper's technique as a collective).

The switch in the paper sits at the aggregation point between workers. On a
TPU fleet the analogous boundary is the data-parallel replica axis (and, on a
multi-pod mesh, the cross-pod hop — the expensive link where an in-network
aggregator would physically sit). All strategies here operate *inside*
``shard_map`` over the replica axes (manual collectives), with the model/TP
axes left automatic.

Strategies
----------
native     : plain float psum — the no-switch baseline.
switchml   : SwitchML (Sapio et al., NSDI'21) reimplementation: per-chunk
             max-exponent round trip (collective #1), int32 fixed-point
             quantize -> int psum (collective #2) -> dequantize. This is the
             baseline the paper improves on.
fpisa      : the paper's technique adapted to TPU: block-exponent planes,
             mantissas aligned with worker-count pre-shift, ONE int32 psum +
             one tiny int32 pmax, delayed renormalization after the collective.
             Bit-reproducible for any reduction order/topology (int add is
             associative + commutative).
fpisa_seq  : bit-faithful switch-arrival semantics (sequential FPISA-A over
             the worker axis via all_gather + scan). Used by accuracy
             experiments; not a production path (W x bytes on the wire).
switch_emu : validation strategy — routes the gathered per-worker gradients
             through the batched switch-dataplane emulator
             (``repro/switchsim``) via a host callback: real slot pool,
             worker bitmaps, streaming window and packetization, lossless
             fabric. Bit-identical to ``fpisa_seq`` (zero-drop arrival order
             is worker-major per chunk). Strictly for validating the
             emulator against the production collectives — never a hot path.

Options
-------
wire_bits  : 32 (default), 16 or 8 — beyond-paper compression: mantissas are
             truncated to the requested element width before the reduction
             (error bound widens by the extra shift; see DESIGN.md §2).
hierarchical: on a multi-pod mesh, reduce-scatter in-pod over `data`, psum
             across `pod`, all-gather in-pod — lets the cross-pod hop use a
             narrower wire than the in-pod hop.
bucket_bytes: tree-level bucketing for ``allreduce_tree`` — the whole
             gradient pytree is flattened into fixed-size block-aligned wire
             buckets, scheduled in reverse-autograd order and dispatched
             double-buffered (core/bucketer.py, DESIGN.md §3/§5). Bit-identical
             to the per-leaf path; 0 = legacy per-leaf tree_map.

Backends
--------
The pre/post-collective transform (encode->align before the psum, decode
after) is pluggable via ``AggConfig.backend``:

``"jnp"``    : pure jnp ``fpisa.encode`` / ``block_decode`` — portable, XLA
               decides the fusion. Reference semantics.
``"pallas"`` : the fused single-pass kernels in ``kernels/fpisa_fused.py`` —
               one HBM read of the gradient and one write of the mantissa
               plane per direction; the (exp, man) planes never round-trip
               through HBM. Mantissas leave the kernel aligned to the LOCAL
               block max; the residual shift to the cross-worker max composes
               exactly on top (arithmetic right shifts compose), so the two
               backends are bit-identical for every strategy, wire width,
               chunking and format. On CPU hosts the kernels run in Pallas
               interpret mode (same semantics, for tests).
``"auto"``   : default — "pallas" on TPU backends, "jnp" elsewhere.

The chunked streaming path (``chunk_elems``) threads the backend through
unchanged: each scanned chunk runs the fused kernel on its own (chunk/block,
block) tile grid, so only one chunk's mantissa plane is ever live — the
whole-tensor planes are never materialized on either backend.

Public API
----------
This module holds the strategy *implementations*; the public aggregation
surface is the :class:`repro.core.agg.Aggregator` facade, where every
strategy below registers itself (``register_strategy``) with its capability
flags. The module-level ``allreduce`` / ``allreduce_tree`` /
``stacked_allreduce[_tree]`` functions are retained as thin deprecation
shims delegating to the facade; ``AggConfig``, ``resolve_backend``,
``BACKENDS`` and ``DEFAULT_BLOCK`` are re-exported from ``repro.core.agg``
for backwards compatibility.
"""
from __future__ import annotations

import math
import warnings
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core import agg as _agg
from repro.core.agg import (  # noqa: F401  (re-exported legacy surface)
    AggConfig, BACKENDS, DEFAULT_BLOCK, register_strategy, resolve_backend,
)
from repro.core import fpisa
from repro.core import numerics as nx
from repro.kernels import fpisa_fused


def _interpret() -> bool:
    # On non-TPU hosts the Pallas kernels run under the interpreter (bit-exact
    # same semantics) so the TPU code path is testable everywhere.
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# backend layer: encode->align (pre-collective) / decode (post-collective)
# ---------------------------------------------------------------------------


def _encode_align(flat: jax.Array, axes, shift: int, cfg: AggConfig, backend: str):
    """flat (N,) packed FP -> (man (N,) int32 aligned to the cross-worker
    block exponent and pre-shifted by ``shift``, bmax (N/block,) int32).

    Runs the tiny per-block max-exponent pmax internally (it must sit between
    the local extract and the final alignment). The pallas backend does the
    extract+local-align in ONE fused HBM pass and finishes with the residual
    per-element shift, which XLA fuses into the wire cast; the jnp backend is
    the reference formulation. Both are bit-identical (shift composition)."""
    if backend == "pallas":
        x2 = flat.reshape(-1, cfg.block)
        man_local, local_bmax = fpisa_fused.fused_encode_align(
            x2, fmt_name=cfg.fmt_name, interpret=_interpret())
        bmax = lax.pmax(local_bmax, axes)
        man = nx.arshift(man_local, (bmax - local_bmax)[:, None] + shift)
        return man.reshape(-1), bmax
    planes = fpisa.encode(flat, cfg.fmt)
    local_bmax = fpisa.block_max_exponent(planes.exp, cfg.block)
    bmax = lax.pmax(local_bmax, axes)
    be = jnp.repeat(bmax, cfg.block, axis=-1)
    man = nx.arshift(planes.man, (be - planes.exp) + shift)
    return man, bmax


def _decode(man_sum: jax.Array, bmax: jax.Array, shift: int, cfg: AggConfig,
            backend: str):
    """(N,) aggregated mantissas (any wire dtype) + (N/block,) block exps ->
    (N,) packed FP via delayed renormalization."""
    if backend == "pallas":
        out2 = fpisa_fused.fused_decode(
            man_sum.reshape(-1, cfg.block), bmax, preshift=shift,
            fmt_name=cfg.fmt_name, interpret=_interpret())
        return out2.reshape(-1)
    return fpisa.block_decode(man_sum.astype(jnp.int32), bmax, cfg.block, shift, cfg.fmt)


def _axis_size(axis_names: Sequence[str]) -> int:
    return math.prod(compat.axis_size(a) for a in axis_names)


def _flatten_pad(x: jax.Array, block: int):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def _unflatten(flat: jax.Array, pad: int, shape, dtype):
    if pad:
        flat = flat[: flat.shape[0] - pad]
    return flat.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# native
# ---------------------------------------------------------------------------


def native_allreduce(x: jax.Array, axis_names: Sequence[str], cfg: AggConfig):
    return lax.psum(x, tuple(axis_names))


# ---------------------------------------------------------------------------
# SwitchML baseline
# ---------------------------------------------------------------------------


def _pow2(e) -> jax.Array:
    """Exact float32 2^e for integer e in [-126, 127], by bit assembly.

    ``jnp.exp2`` is off by ulps for |e| >~ 64 on some XLA CPU backends, which
    silently breaks exact power-of-two rescaling; building the exponent field
    directly is exact by construction."""
    return nx.bitcast_i32_to_f32((jnp.asarray(e, jnp.int32) + 127) << 23)


def switchml_allreduce(x: jax.Array, axis_names: Sequence[str], cfg: AggConfig):
    """Fixed-point aggregation with a per-chunk scale-factor round trip.

    Mirrors SwitchML's host logic: chunk c uses scale 2^(man_bits) / 2^e_max(c)
    where e_max is agreed via a *separate collective round* (the overhead FPISA
    eliminates). Values are quantized to ints, int-psum'd, dequantized.

    The scale exponent k = man_bits - s - (e_max - bias) reaches +-~150 at the
    exponent extremes, past float32's 2^+-126 — a single ``exp2(k)`` factor
    goes inf for blocks whose max is a small normal (flushing them to zero
    through inf/NaN laundering), and ``exp2`` itself is not even exact for
    |k| >~ 64 on some XLA backends. The scale is therefore applied as two
    bit-assembled power-of-two half-factors (exact by construction), so every
    multiply is an exact scaling and in-range blocks quantize identically to
    the ideal single-factor formulation. All-zero / all-denormal blocks
    (e_max == 0) have no finite scale and quantize to exactly 0 by definition
    (see tests/test_wire_edges.py).
    """
    axes = tuple(axis_names)
    w = _axis_size(axes)
    fmt = cfg.fmt
    orig_shape, orig_dtype = x.shape, x.dtype
    flat, pad = _flatten_pad(x.astype(jnp.float32), cfg.block)

    planes = fpisa.encode(flat, fmt)
    local_bmax = fpisa.block_max_exponent(planes.exp, cfg.block)
    # ---- round 1: max-exponent agreement (extra RTT in SwitchML) ----
    bmax = lax.pmax(local_bmax, axes)

    # quantize: x / 2^(bmax - bias) * 2^(man_bits - s); s guards the int32 sum
    s = nx.required_preshift(w, fmt)
    be = jnp.repeat(bmax, cfg.block, axis=-1)
    k = (fmt.man_bits - s) - (be - fmt.bias)
    k1 = k // 2
    k2 = k - k1
    live = be > 0
    q = jnp.where(
        live, jnp.round((flat * _pow2(k1)) * _pow2(k2)), 0.0,
    ).astype(jnp.int32)
    # ---- round 2: integer aggregation (the in-switch op) ----
    qsum = lax.psum(q, axes)
    out = jnp.where(
        live, (qsum.astype(jnp.float32) * _pow2(-k1)) * _pow2(-k2), 0.0)
    return _unflatten(out, pad, orig_shape, orig_dtype)


# ---------------------------------------------------------------------------
# FPISA production path
# ---------------------------------------------------------------------------


def _check_wire_capacity(w: int, wire_bits: int) -> None:
    """No shift can make a narrow wire safe beyond w = 2^(wire_bits - 1)
    summands: the arithmetic right shift floors every negative mantissa at -1
    (round toward -inf), so a same-signed reduction can always reach -w —
    past the wire dtype's negative rail once w exceeds it. Refused loudly
    rather than silently wrapping (see tests/test_wire_edges.py)."""
    if wire_bits < 32 and w > 1 << (wire_bits - 1):
        raise ValueError(
            f"wire_bits={wire_bits} cannot carry a {w}-way sum: negative "
            f"mantissas floor at -1 under the arithmetic pre-shift, so the "
            f"reduction can reach -{w} < -2^{wire_bits - 1}")


def _wire_shift(fmt: fpisa.FpFormat, w: int, wire_bits: int) -> int:
    """Extra right-shift so each aligned mantissa fits in `wire_bits` signed
    ints AND the integer sum over w workers cannot overflow the wire dtype
    during an associative reduction (DESIGN.md §2)."""
    s = nx.required_preshift(w, fmt)
    if wire_bits >= 32:
        return s
    _check_wire_capacity(w, wire_bits)
    # element magnitude < 2^(man_bits + 1 - total_shift); need the *sum* to fit:
    # w * 2^(man_bits + 1 - t) <= 2^(wire_bits - 1)
    t = fmt.man_bits + 1 + math.ceil(math.log2(max(w, 1))) - (wire_bits - 1)
    return max(s, t)


_PACKED = {"fp32": jnp.float32, "fp16": jnp.float16, "bf16": jnp.bfloat16}


def _wire_cast(man: jax.Array, wire_bits: int) -> jax.Array:
    """Cast a mantissa plane to the wire element dtype (lossless: the wire
    shift guarantees every value — and every partial sum — fits)."""
    if wire_bits == 16:
        return man.astype(jnp.int16)
    if wire_bits == 8:
        return man.astype(jnp.int8)
    return man


def fpisa_allreduce(x: jax.Array, axis_names: Sequence[str], cfg: AggConfig):
    """The paper's aggregation mapped to TPU collectives (see module doc).

    The input is handled in the *format's* packed dtype — aggregating bf16
    gradients with ``fmt_name='bf16'`` never materializes an f32 copy and
    its mantissa planes fit int16 natively (9-bit magnitude + headroom)."""
    axes = tuple(axis_names)
    w = _axis_size(axes)
    fmt = cfg.fmt
    backend = resolve_backend(cfg.backend)
    orig_shape, orig_dtype = x.shape, x.dtype
    flat, pad = _flatten_pad(x.astype(_PACKED[cfg.fmt_name]), cfg.block)

    shift = _wire_shift(fmt, w, cfg.wire_bits)
    # The per-block max-exponent pmax inside _encode_align is a tiny
    # collective: one int per block (1/block of the data, and it can ride in
    # int8 on real hardware). Unlike SwitchML this is NOT a host round trip;
    # it pipelines with the mantissa pass chunk-by-chunk.
    man, bmax = _encode_align(flat, axes, shift, cfg, backend)
    man = _wire_cast(man, cfg.wire_bits)
    man_sum = lax.psum(man, axes)
    out = _decode(man_sum, bmax, shift, cfg, backend)
    return _unflatten(out, pad, orig_shape, orig_dtype)


def _hier_collect(man: jax.Array, data_axis: str, pod_axis: str,
                  cfg: AggConfig, shift: int):
    """Two-level integer collective: in-pod reduce-scatter + cross-pod psum.

    Returns (man_shard, pod_shift). Split out of the monolithic hierarchical
    path so the bucketer's double-buffered dispatch (core/bucketer.py) can
    overlap this phase with the encode of the next bucket.
    """
    fmt = cfg.fmt
    w_data = compat.axis_size(data_axis)
    w_pod = compat.axis_size(pod_axis)
    # level 1: in-pod reduce-scatter (int32 wire on ICI)
    man_shard = lax.psum_scatter(man, data_axis, scatter_dimension=0, tiled=True)
    # level 2: cross-pod integer psum, optionally narrow wire. The in-pod
    # partial sums carry up to man_bits+1+log2(w_data) magnitude bits; a
    # narrower cross-pod wire requires one extra truncating shift, applied
    # ONCE, after the full-precision in-pod reduction (optimal ordering:
    # precision is only given up on the expensive hop).
    pod_bits = cfg.pod_wire_bits or cfg.wire_bits
    pod_shift = 0
    if pod_bits < 32:
        # same floor-at--1 rail as _wire_shift, for the cross-pod summand count
        _check_wire_capacity(w_pod, pod_bits)
        partial_mag_bits = (fmt.man_bits + 1 - shift) + math.ceil(math.log2(max(w_data, 1)))
        pod_shift = max(0, partial_mag_bits + math.ceil(math.log2(max(w_pod, 1))) - (pod_bits - 1))
        man_shard = nx.arshift(man_shard, pod_shift)
        if pod_bits == 16:
            man_shard = man_shard.astype(jnp.int16)
        elif pod_bits == 8:
            man_shard = man_shard.astype(jnp.int8)
    man_shard = lax.psum(man_shard, pod_axis)
    return man_shard, pod_shift


def _hier_finish(man_shard: jax.Array, bmax: jax.Array, shift: int,
                 pod_shift: int, data_axis: str, cfg: AggConfig, backend: str):
    """Delayed renorm on the owned shard only, then gather packed FP."""
    w_data = compat.axis_size(data_axis)
    idx = lax.axis_index(data_axis)
    blocks_per_shard = bmax.shape[0] // w_data
    bmax_shard = lax.dynamic_slice_in_dim(bmax, idx * blocks_per_shard, blocks_per_shard)
    out_shard = _decode(man_shard, bmax_shard, shift + pod_shift, cfg, backend)
    return lax.all_gather(out_shard, data_axis, axis=0, tiled=True)


def fpisa_allreduce_hierarchical(
    x: jax.Array,
    data_axis: str,
    pod_axis: str,
    cfg: AggConfig,
):
    """Two-level FPISA aggregation for the multi-pod mesh.

    In-pod (ICI, cheap): reduce_scatter int32 mantissas over `data`.
    Cross-pod (DCI, expensive): psum over `pod`, optionally narrower wire.
    In-pod: all_gather the renormalized result.
    Exponent agreement is global (pmax over both axes) so mantissa scales are
    compatible across levels; the sum stays in integer domain end-to-end and
    renormalization happens ONCE (delayed, as in the paper).
    """
    w_data = compat.axis_size(data_axis)
    w_pod = compat.axis_size(pod_axis)
    w = w_data * w_pod
    fmt = cfg.fmt
    backend = resolve_backend(cfg.backend)
    orig_shape, orig_dtype = x.shape, x.dtype
    # pad to block * w_data so reduce_scatter tiles evenly
    quantum = cfg.block * w_data
    flat = x.reshape(-1).astype(_PACKED[cfg.fmt_name])
    pad = (-flat.shape[0]) % quantum
    if pad:
        flat = jnp.pad(flat, (0, pad))

    shift = _wire_shift(fmt, w, cfg.wire_bits)
    # exponent agreement is global (pmax over both axes) so mantissa scales
    # are compatible across both reduction levels
    man, bmax = _encode_align(flat, (data_axis, pod_axis), shift, cfg, backend)
    man_shard, pod_shift = _hier_collect(man, data_axis, pod_axis, cfg, shift)
    out = _hier_finish(man_shard, bmax, shift, pod_shift, data_axis, cfg, backend)
    return _unflatten(out, pad, orig_shape, orig_dtype)


# ---------------------------------------------------------------------------
# bit-faithful sequential variant (accuracy experiments)
# ---------------------------------------------------------------------------


def fpisa_seq_allreduce(x: jax.Array, axis_names: Sequence[str], cfg: AggConfig):
    axes = tuple(axis_names)
    stacked = lax.all_gather(x.astype(jnp.float32).reshape(-1), axes)
    stacked = stacked.reshape(-1, x.size)
    out = fpisa.fpisa_sum_sequential(stacked, cfg.fmt, variant="fpisa_a")
    return out.reshape(x.shape).astype(x.dtype)


def switch_emu_allreduce(x: jax.Array, axis_names: Sequence[str], cfg: AggConfig):
    """Validation strategy: all_gather the per-worker shards, then run the
    real gradient through the batched switch-dataplane emulator on the host
    (``jax.pure_callback``). Exercises the full protocol machinery — slot
    claim/recycle, bitmaps, packetized streaming window — on a lossless
    fabric, so the result is bit-identical to ``fpisa_seq`` (worker-major
    arrival order per chunk). See repro/switchsim/dataplane.py.

    With ``cfg.switch_shared`` set, the traffic instead rides the named
    process-shared multi-tenant dataplane as tenant ``cfg.switch_job`` of
    ``cfg.switch_jobs`` — several jobs' aggregators (plus query streams)
    then contend for one emulated switch with QoS-aware slot admission
    (repro/switchsim/tenancy.py, DESIGN.md §10). The aggregated bits are
    unchanged: a lossless fabric delivers every result regardless of how
    admission interleaves the claims."""
    if cfg.fmt_name != "fp32":
        raise ValueError(
            "switch_emu runs on the jax-free numpy dataplane, which is "
            f"fp32-only; got fmt_name={cfg.fmt_name!r}")
    axes = tuple(axis_names)
    w = _axis_size(axes)
    stacked = lax.all_gather(x.astype(jnp.float32).reshape(-1), axes)
    stacked = stacked.reshape(-1, x.size)

    def host(vals):
        from repro import switchsim

        # NumpyDataplane, NOT the jitted one: concurrent host callbacks that
        # re-enter jax deadlock the CPU client (see switchsim/npfpisa.py).
        if cfg.switch_shared is not None:
            return switchsim.shared_emulated_allreduce(
                cfg.switch_shared, np.asarray(vals),
                num_jobs=cfg.switch_jobs, job=cfg.switch_job)
        dp = switchsim.NumpyDataplane(switchsim.DataplaneConfig(
            num_workers=w, fmt_name="fp32", variant="fpisa_a"))
        return switchsim.run_aggregation(dp, np.asarray(vals)).astype(np.float32)

    out = jax.pure_callback(
        host, jax.ShapeDtypeStruct((x.size,), jnp.float32), stacked)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# stacked (logical-worker) aggregation — elastic fault tolerance (DESIGN.md §8)
# ---------------------------------------------------------------------------
#
# ``stacked_*`` variants reduce over a LEADING logical-worker axis as well as
# the mesh axes: x has shape (k, ...) where this shard hosts k of the job's
# W = k * mesh_size logical workers. The reduction over logical workers runs
# entirely in the integer domain (mantissa planes for fpisa, fixed-point for
# switchml, arrival-ordered planes for fpisa_seq/switch_emu), and the wire
# shift is derived from W — NOT the mesh size — so the aggregated bits are
# IDENTICAL for any distribution of the W workers over any mesh. That is the
# property elastic recovery rests on: after a host death the survivors re-mesh
# with k' > k workers per shard and the training trajectory continues bit-for-
# bit (runtime/controller.py, tests/test_recovery.py). ``native`` is provided
# for completeness but sums in float, which is grouping-sensitive — it does
# not carry the bit-identity guarantee.


def _stacked_rows(x: jax.Array, dtype) -> jax.Array:
    if x.ndim < 1:
        raise ValueError("stacked aggregation needs a leading worker axis")
    return x.reshape(x.shape[0], -1).astype(dtype)


def _encode_align_stacked(rows: jax.Array, axes, shift: int, cfg: AggConfig,
                          backend: str):
    """rows (k, Nb) packed FP -> (man (k, Nb) int32 aligned to the block
    exponent maxed across ALL W logical workers, bmax (Nb/block,) int32).

    The block max folds the local worker axis with ``jnp.max`` before the
    cross-shard ``pmax`` — max is associative, so the agreed exponent (and
    with it every aligned mantissa) is independent of the worker placement."""
    k, nb_elems = rows.shape
    nblocks = nb_elems // cfg.block
    if backend == "pallas":
        man_local, local_bmax = fpisa_fused.fused_encode_align(
            rows.reshape(-1, cfg.block), fmt_name=cfg.fmt_name,
            interpret=_interpret())
        local_bmax = local_bmax.reshape(k, nblocks)
        bmax = lax.pmax(jnp.max(local_bmax, axis=0), axes)
        man = nx.arshift(man_local.reshape(k, nblocks, cfg.block),
                         (bmax[None, :] - local_bmax)[:, :, None] + shift)
        return man.reshape(k, nb_elems), bmax
    planes = fpisa.encode(rows, cfg.fmt)
    local_bmax = fpisa.block_max_exponent(planes.exp, cfg.block)  # (k, nblocks)
    bmax = lax.pmax(jnp.max(local_bmax, axis=0), axes)
    be = jnp.repeat(bmax, cfg.block)[None, :]
    man = nx.arshift(planes.man, (be - planes.exp) + shift)
    return man, bmax


def _stacked_pad(rows: jax.Array, quantum: int):
    pad = (-rows.shape[1]) % quantum
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad)))
    return rows, pad


def stacked_native_allreduce(x, axis_names: Sequence[str], cfg: AggConfig):
    return lax.psum(jnp.sum(x, axis=0), tuple(axis_names))


def stacked_fpisa_allreduce(x, axis_names: Sequence[str], cfg: AggConfig):
    """FPISA aggregation over (leading logical-worker axis) + mesh axes.

    Each logical worker's mantissas are individually wire-cast (its packet
    payload), summed over the local workers in int32 — exact, every partial
    fits the wire dtype by the W-derived shift — then psum'd across shards.
    Integer addition is associative + commutative, so the result is bit-
    identical for every placement of the W workers. A 2-axis (pod, data) mesh
    is reduced jointly (flat): hierarchical striping is a routing choice and
    the flat integer sum is bit-identical to it at equal W."""
    axes = tuple(axis_names)
    k = x.shape[0]
    w = k * _axis_size(axes)
    backend = resolve_backend(cfg.backend)
    orig_shape, orig_dtype = x.shape[1:], x.dtype
    rows, pad = _stacked_pad(_stacked_rows(x, _PACKED[cfg.fmt_name]), cfg.block)

    shift = _wire_shift(cfg.fmt, w, cfg.wire_bits)
    man, bmax = _encode_align_stacked(rows, axes, shift, cfg, backend)
    man = _wire_cast(man, cfg.wire_bits)  # per-worker wire payloads
    local = _wire_cast(jnp.sum(man.astype(jnp.int32), axis=0), cfg.wire_bits)
    man_sum = lax.psum(local, axes)
    out = _decode(man_sum, bmax, shift, cfg, backend)
    return _unflatten(out, pad, orig_shape, orig_dtype)


def stacked_switchml_allreduce(x, axis_names: Sequence[str], cfg: AggConfig):
    """SwitchML fixed-point aggregation with W logical workers (see
    ``switchml_allreduce`` for the scale-factor mechanics): per-worker
    quantization, exact int32 local fold, int psum — same invariance
    argument as ``stacked_fpisa_allreduce``."""
    axes = tuple(axis_names)
    k = x.shape[0]
    w = k * _axis_size(axes)
    fmt = cfg.fmt
    orig_shape, orig_dtype = x.shape[1:], x.dtype
    rows, pad = _stacked_pad(_stacked_rows(x, jnp.float32), cfg.block)

    planes = fpisa.encode(rows, fmt)
    local_bmax = fpisa.block_max_exponent(planes.exp, cfg.block)
    bmax = lax.pmax(jnp.max(local_bmax, axis=0), axes)

    s = nx.required_preshift(w, fmt)
    be = jnp.repeat(bmax, cfg.block)  # (Nb,)
    kexp = (fmt.man_bits - s) - (be - fmt.bias)
    k1 = kexp // 2
    k2 = kexp - k1
    live = be > 0
    q = jnp.where(live[None, :],
                  jnp.round((rows * _pow2(k1)[None, :]) * _pow2(k2)[None, :]),
                  0.0).astype(jnp.int32)
    qsum = lax.psum(jnp.sum(q, axis=0), axes)
    out = jnp.where(
        live, (qsum.astype(jnp.float32) * _pow2(-k1)) * _pow2(-k2), 0.0)
    return _unflatten(out, pad, orig_shape, orig_dtype)


def _gather_logical(x, axes):
    """(k, ...) per-shard stacks -> (W, N) rows in logical-worker order.

    Logical workers are assigned to shards contiguously (shard d hosts
    workers [d*k, (d+1)*k)), so the device-major all_gather concatenation IS
    the logical order — on every mesh size."""
    k = x.shape[0]
    rows = x.astype(jnp.float32).reshape(k, -1)
    return lax.all_gather(rows, axes).reshape(-1, rows.shape[-1])


def stacked_fpisa_seq_allreduce(x, axis_names: Sequence[str], cfg: AggConfig):
    stacked = _gather_logical(x, tuple(axis_names))
    out = fpisa.fpisa_sum_sequential(stacked, cfg.fmt, variant="fpisa_a")
    return out.reshape(x.shape[1:]).astype(x.dtype)


def stacked_switch_emu_allreduce(x, axis_names: Sequence[str], cfg: AggConfig):
    """Validation strategy with W logical switch ports: the gathered per-
    worker gradients stream through the numpy dataplane exactly as in
    ``switch_emu_allreduce`` — arrival order is logical-worker-major, i.e.
    identical on every mesh, so kill-and-resume trajectories stay bit-exact
    even under the full protocol emulation."""
    if cfg.fmt_name != "fp32":
        raise ValueError(
            "switch_emu runs on the jax-free numpy dataplane, which is "
            f"fp32-only; got fmt_name={cfg.fmt_name!r}")
    if cfg.switch_shared is not None:
        raise ValueError(
            "switch_shared tenancy is wired for the flat switch_emu path; "
            "the stacked (elastic logical-worker) variant does not support "
            "a shared dataplane")
    axes = tuple(axis_names)
    w = x.shape[0] * _axis_size(axes)
    n = math.prod(x.shape[1:]) if x.ndim > 1 else 1
    stacked = _gather_logical(x, axes)

    def host(vals):
        from repro import switchsim

        dp = switchsim.NumpyDataplane(switchsim.DataplaneConfig(
            num_workers=w, fmt_name="fp32", variant="fpisa_a"))
        return switchsim.run_aggregation(dp, np.asarray(vals)).astype(np.float32)

    out = jax.pure_callback(
        host, jax.ShapeDtypeStruct((n,), jnp.float32), stacked)
    return out.reshape(x.shape[1:]).astype(x.dtype)


# ---------------------------------------------------------------------------
# split-phase pipeline factories (bucketer hooks, DESIGN.md §3/§5)
# ---------------------------------------------------------------------------


def _fpisa_flat_phases(axes, cfg: AggConfig, backend: str):
    """(encode, collect, finish) for the flat single-level fpisa path —
    mirrors ``fpisa_allreduce`` exactly (bucket buffers are already block
    multiples, so its pad step is a no-op here)."""
    w = _axis_size(axes)
    shift = _wire_shift(cfg.fmt, w, cfg.wire_bits)

    def encode(flat):
        man, bmax = _encode_align(flat, axes, shift, cfg, backend)
        return _wire_cast(man, cfg.wire_bits), bmax

    def collect(state):
        man, bmax = state
        return lax.psum(man, axes), bmax

    def finish(state):
        man_sum, bmax = state
        return _decode(man_sum, bmax, shift, cfg, backend)

    return encode, collect, finish


def _fpisa_hier_phases(data_axis, pod_axis, cfg: AggConfig, backend: str,
                       stripe: int):
    """(encode, collect, finish) for the hierarchical fpisa path.

    ``stripe`` rotates the in-pod reduce-scatter shard assignment of this
    bucket by whole shards (a block-multiple roll): bucket i's cross-pod hop
    and delayed renorm for any given gradient range land on data-rank
    (rank + i) % w_data, striping consecutive buckets' DCI traffic across the
    pod axis's uplinks. Rolling by whole shards keeps every block's contents
    intact, so the result is bit-identical to the unstriped path.
    """
    w_data = compat.axis_size(data_axis)
    w_pod = compat.axis_size(pod_axis)
    shift = _wire_shift(cfg.fmt, w_data * w_pod, cfg.wire_bits)
    quantum = cfg.block * w_data

    def encode(flat):
        pad = (-flat.shape[0]) % quantum
        if pad:
            flat = jnp.pad(flat, (0, pad))
        roll = (stripe % w_data) * (flat.shape[0] // w_data)
        if roll:
            flat = jnp.roll(flat, -roll)
        man, bmax = _encode_align(
            flat, (data_axis, pod_axis), shift, cfg, backend)
        return man, bmax, pad, roll

    def collect(state):
        man, bmax, pad, roll = state
        man_shard, pod_shift = _hier_collect(man, data_axis, pod_axis, cfg, shift)
        return man_shard, bmax, pod_shift, pad, roll

    def finish(state):
        man_shard, bmax, pod_shift, pad, roll = state
        out = _hier_finish(man_shard, bmax, shift, pod_shift, data_axis,
                           cfg, backend)
        if roll:
            out = jnp.roll(out, roll)
        if pad:
            out = out[:out.shape[0] - pad]
        return out

    return encode, collect, finish


def _fpisa_stacked_phases(axes, cfg: AggConfig, backend: str, k: int):
    """(encode, collect, finish) for the stacked flat fpisa path — mirrors
    ``stacked_fpisa_allreduce``: per-worker encode + exact local int fold
    before the wire, W-derived shift, one delayed renorm after the psum."""
    w = k * _axis_size(axes)
    shift = _wire_shift(cfg.fmt, w, cfg.wire_bits)

    def encode(buf):  # (k, elems) packed FP
        man, bmax = _encode_align_stacked(buf, axes, shift, cfg, backend)
        man = _wire_cast(man, cfg.wire_bits)
        local = _wire_cast(jnp.sum(man.astype(jnp.int32), axis=0),
                           cfg.wire_bits)
        return local, bmax

    def collect(state):
        man, bmax = state
        return lax.psum(man, axes), bmax

    def finish(state):
        man_sum, bmax = state
        return _decode(man_sum, bmax, shift, cfg, backend)

    return encode, collect, finish


# ---------------------------------------------------------------------------
# registry (repro.core.agg) — the declarative strategy table. Capability
# flags are validated once at Aggregator construction; the bucketer pulls the
# split-phase pipeline hooks and staging dtypes from the same specs.
# ---------------------------------------------------------------------------


def _validate_switch_emu(cfg: AggConfig) -> None:
    if cfg.fmt_name != "fp32":
        raise ValueError(
            "switch_emu runs on the jax-free numpy dataplane, which is "
            f"fp32-only; got fmt_name={cfg.fmt_name!r}")


def _stage_native(cfg: AggConfig, group: str):
    return jnp.dtype(group)  # native psums in the leaf dtype


def _stage_packed(cfg: AggConfig, group: str):
    return _PACKED[cfg.fmt_name]


register_strategy(
    "native", stacked=stacked_native_allreduce, chunk_noop=True,
    stage_dtype=_stage_native,
    description="plain float psum — the no-switch baseline",
)(native_allreduce)

register_strategy(
    "switchml", stacked=stacked_switchml_allreduce,
    description="SwitchML int32 fixed-point with a scale-factor round trip",
)(switchml_allreduce)

register_strategy(
    "fpisa", stacked=stacked_fpisa_allreduce,
    hierarchical=fpisa_allreduce_hierarchical,
    stage_dtype=_stage_packed,
    flat_phases=_fpisa_flat_phases, hier_phases=_fpisa_hier_phases,
    stacked_phases=_fpisa_stacked_phases,
    description="the paper's block-exponent integer planes (production path)",
)(fpisa_allreduce)

register_strategy(
    "fpisa_seq", stacked=stacked_fpisa_seq_allreduce,
    description="bit-faithful sequential switch-arrival FPISA-A",
)(fpisa_seq_allreduce)

register_strategy(
    "switch_emu", stacked=stacked_switch_emu_allreduce,
    requires_host_callback=True, validate=_validate_switch_emu,
    description="validation via the batched switch-dataplane emulator",
)(switch_emu_allreduce)


# ---------------------------------------------------------------------------
# deprecation shims — the legacy module-level surface. They delegate to the
# Aggregator facade unchanged (same dispatch, bit for bit) and warn with the
# CALLER attributed (stacklevel), so the suite can refuse in-tree use while
# out-of-tree users keep working. New code: repro.core.agg.Aggregator.
# ---------------------------------------------------------------------------


def _facade_shim_warn(name: str) -> None:
    warnings.warn(
        f"repro.core.allreduce.{name}() is deprecated; construct a "
        f"repro.core.agg.Aggregator once and call its methods instead",
        DeprecationWarning, stacklevel=3)


def allreduce(x: jax.Array, axis_names: Sequence[str], cfg: AggConfig):
    """Deprecated shim: ``Aggregator(cfg, axis_names).allreduce(x)``."""
    _facade_shim_warn("allreduce")
    return _agg.Aggregator(cfg, axis_names).allreduce(x)


def allreduce_tree(tree, axis_names: Sequence[str], cfg: AggConfig):
    """Deprecated shim: ``Aggregator(cfg, axis_names).allreduce_tree(tree)``."""
    _facade_shim_warn("allreduce_tree")
    return _agg.Aggregator(cfg, axis_names).allreduce_tree(tree)


def stacked_allreduce(x: jax.Array, axis_names: Sequence[str], cfg: AggConfig):
    """Deprecated shim: ``Aggregator(cfg, axis_names, stacked=True)
    .allreduce(x)`` (leading logical-worker axis, see section doc)."""
    _facade_shim_warn("stacked_allreduce")
    if cfg.chunk_elems:
        # preserved shim behavior: the facade refuses this at construction
        # with ValueError; the legacy function raised NotImplementedError
        raise NotImplementedError(
            "chunk_elems is not supported with stacked (logical-worker) "
            "aggregation; use bucket_bytes to bound transient memory instead")
    return _agg.Aggregator(cfg, axis_names, stacked=True).allreduce(x)


def stacked_allreduce_tree(tree, axis_names: Sequence[str], cfg: AggConfig):
    """Deprecated shim: ``Aggregator(cfg, axis_names, stacked=True)
    .allreduce_tree(tree)``."""
    _facade_shim_warn("stacked_allreduce_tree")
    return _agg.Aggregator(cfg, axis_names, stacked=True).allreduce_tree(tree)
