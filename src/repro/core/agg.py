"""Unified ``Aggregator`` facade with a pluggable strategy registry.

FPISA's value proposition is that in-switch floating-point aggregation is a
drop-in substitute for host collectives — so the repo's aggregation surface
must itself be drop-in. This module is the ONE public entry point:

* :class:`AggConfig`    — every aggregation knob (strategy, backend, wire
                          widths, chunking, bucketing) in one frozen config.
* :class:`Aggregator`   — the facade. Constructed once from an ``AggConfig``
                          plus the mesh axis names it reduces over, it owns
                          strategy lookup, backend resolution, chunked
                          streaming, hierarchical routing, logical-worker
                          stacking, and tree-level bucketing behind two calls:
                          ``agg.allreduce(x)`` and ``agg.allreduce_tree(tree)``.
                          All capability validation happens at construction —
                          a bad combination fails with a named, actionable
                          error before anything is traced.
* :func:`register_strategy` — the registry. Strategies declare themselves
                          (flat fn, optional stacked/hierarchical variants,
                          optional split-phase pipeline hooks for the
                          bucketer) with capability flags instead of being
                          hand-threaded through dispatch dicts and
                          ``if``/``elif`` special cases. A new strategy — a
                          NetFC-style table lookup, a different emulator —
                          plugs in with one call and is immediately reachable
                          from every consumer (train step, elastic controller,
                          launchers, examples, benchmarks, serving).
* :func:`add_agg_args` / :meth:`AggConfig.from_args` — the one place CLI flag
                          threading lives. Every entry point calls the pair
                          instead of re-declaring ``--agg-*`` flags by hand.

The strategy *implementations* live in ``repro.core.allreduce`` (the math),
which registers them here at import time. The legacy module-level functions
(``allreduce``, ``allreduce_tree``, ``stacked_allreduce[_tree]``) remain as
thin deprecation shims delegating to this facade.

Capability matrix of the built-in strategies (DESIGN.md §9):

========== ======== ======== ============ ============= ==============
strategy   chunking stacking hierarchical host callback split-phase
========== ======== ======== ============ ============= ==============
native     no-op    yes      —            no            —
switchml   yes      yes      —            no            —
fpisa      yes      yes      yes          no            flat/hier/stacked
fpisa_seq  yes      yes      —            no            —
switch_emu yes      yes      —            yes           —
========== ======== ======== ============ ============= ==============
"""
from __future__ import annotations

import argparse
import dataclasses
import difflib
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import trace as _trace

DEFAULT_BLOCK = 256

BACKENDS = ("auto", "jnp", "pallas")


def _did_you_mean(name: str, options: Sequence[str]) -> str:
    close = difflib.get_close_matches(name, options, n=1)
    return f"; did you mean {close[0]!r}?" if close else ""


# Measured "auto" choice per jax platform. Pallas wins only where the fused
# kernels actually beat jnp: on TPU the mosaic kernels fuse encode+align into
# one VMEM pass; on CPU the Triton/interpreter path is ~2.2x SLOWER than jnp
# (BENCH_roofline: fused 4.1 ms vs jnp 1.9 ms for the 16M-elem transform), so
# auto must resolve to jnp there — regression-pinned by tests/test_agg.py.
_AUTO_BACKEND = {
    "tpu": "pallas",
    "gpu": "jnp",  # pallas-on-gpu unmeasured here; jnp is the safe default
    "cpu": "jnp",
}


def resolve_backend(backend: str) -> str:
    """Map "auto" to the measured-fastest backend for the current jax
    platform (``_AUTO_BACKEND``; unlisted platforms fall back to jnp).

    Unknown names fail here with the valid options and the nearest match,
    not as a KeyError deep inside a traced function."""
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown aggregation backend {backend!r}; valid backends: "
            f"{', '.join(BACKENDS)}{_did_you_mean(backend, BACKENDS)}")
    if backend == "auto":
        return _AUTO_BACKEND.get(jax.default_backend(), "jnp")
    return backend


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AggConfig:
    """Every aggregation knob in one frozen config (strategy docs in
    ``repro.core.allreduce``; facade + registry docs in this module)."""

    strategy: str = "fpisa"  # any name in available_strategies()
    block: int = DEFAULT_BLOCK
    wire_bits: int = 32
    fmt_name: str = "fp32"
    # wire bits for the cross-pod hop when hierarchical (defaults to wire_bits)
    pod_wire_bits: int | None = None
    # process the flattened gradient in chunks of this many elements (scan):
    # bounds the transient f32/int32 plane memory to O(chunk) instead of
    # O(total params) — a 20B-param model otherwise materializes ~160 GB of
    # planes. 0 disables chunking. Chunking also matches the switch reality:
    # aggregation is streamed per-packet, never whole-tensor.
    chunk_elems: int = 0
    # encode/decode transform backend: "jnp" | "pallas" | "auto"
    backend: str = "auto"
    # tree-level bucketing (core/bucketer.py): flatten the gradient pytree
    # into fixed-size wire buckets (leaf offsets padded to block boundaries so
    # every strategy stays bit-identical to the per-leaf path) and dispatch
    # them double-buffered. 0 = legacy per-leaf tree_map. See DESIGN.md §3.
    bucket_bytes: int = 0
    # multi-tenant switch emulation (switch_emu only, DESIGN.md §10): name a
    # process-shared emulated dataplane and this aggregator's tenant on it,
    # so several jobs (plus query streams) contend for one switch. None =
    # a private single-tenant dataplane per call (the default behavior).
    switch_shared: str | None = None
    switch_jobs: int = 1
    switch_job: int = 0

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}"
                f"{_did_you_mean(self.backend, BACKENDS)}")
        if not 0 <= self.switch_job < self.switch_jobs:
            raise ValueError(
                f"switch_job must be in [0, switch_jobs={self.switch_jobs}), "
                f"got {self.switch_job}")

    @property
    def fmt(self):
        from repro.core import fpisa

        return fpisa.FORMATS[self.fmt_name]

    @classmethod
    def from_args(cls, ns: argparse.Namespace) -> "AggConfig":
        """Build the config from a namespace produced by a parser that went
        through :func:`add_agg_args` — the single CLI threading point.

        Validates strategy and backend immediately (named options + nearest
        match) so a typo'd flag fails at the command line, not mid-trace.

        ``--bucket-bytes auto`` resolves HERE, once, to a concrete byte
        count via the cost-model autotuner (``repro.autotune``): the trace
        named by ``--autotune-trace`` (or $REPRO_AUTOTUNE_TRACE) is fitted
        and the candidate sweep picks the plan; with no trace available it
        falls back loudly to the measured-good default. The config itself
        always carries an int, so everything downstream (hashing, jit
        caching, the bucketer) is unchanged."""
        bucket_bytes = getattr(ns, "bucket_bytes", 0)
        if isinstance(bucket_bytes, str):
            from repro.autotune import search as _search

            bucket_bytes = _search.auto_bucket_bytes(
                trace_path=getattr(ns, "autotune_trace", None),
                block=getattr(ns, "agg_block", None) or DEFAULT_BLOCK)
        cfg = cls(
            strategy=getattr(ns, "agg_strategy", "fpisa"),
            backend=getattr(ns, "agg_backend", "auto"),
            wire_bits=getattr(ns, "agg_wire_bits", None) or 32,
            pod_wire_bits=getattr(ns, "agg_pod_wire_bits", None),
            fmt_name=getattr(ns, "agg_fmt", None) or "fp32",
            chunk_elems=getattr(ns, "agg_chunk", 0),
            bucket_bytes=bucket_bytes,
            block=getattr(ns, "agg_block", None) or DEFAULT_BLOCK,
        )
        get_strategy(cfg.strategy)   # raises with options + nearest match
        resolve_backend(cfg.backend)
        return cfg


def _bucket_bytes_flag(value: str):
    """argparse type for ``--bucket-bytes``: an int, or the literal "auto"
    (resolved by the cost-model autotuner in ``AggConfig.from_args``)."""
    if value.strip().lower() == "auto":
        return "auto"
    try:
        return int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--bucket-bytes expects an integer byte count or 'auto', "
            f"got {value!r}") from None


def add_agg_args(parser: argparse.ArgumentParser, *,
                 default_strategy: str = "fpisa"):
    """Register the shared ``--agg-*`` flags on ``parser``.

    Every entry point (launchers, examples, serving, benchmarks) calls this
    instead of declaring its own copies; ``AggConfig.from_args`` turns the
    parsed namespace back into a config. Legacy spellings (``--agg``,
    ``--wire-bits``, ``--pod-wire-bits``) are kept as aliases."""
    g = parser.add_argument_group(
        "aggregation", "FPISA aggregation facade (repro.core.agg)")
    g.add_argument(
        "--agg-strategy", "--agg", dest="agg_strategy",
        default=default_strategy, metavar="NAME",
        help="aggregation strategy (registry: "
             f"{', '.join(available_strategies()) or 'populated at runtime'})")
    g.add_argument(
        "--agg-backend", default="auto", metavar="NAME",
        help="pre/post-collective transform backend: auto | jnp | pallas "
             "(fused Pallas kernels on TPU; pure jnp elsewhere)")
    g.add_argument(
        "--agg-chunk", type=int, default=0, metavar="N",
        help="stream the aggregation through chunks of this many elements "
             "(bounds transient plane memory; 0 = whole-tensor)")
    g.add_argument(
        "--bucket-bytes", type=_bucket_bytes_flag, default=0, metavar="N",
        help="flatten the gradient pytree into fixed-size block-aligned wire "
             "buckets dispatched double-buffered (core/bucketer.py; "
             "bit-identical to per-leaf; 0 = per-leaf tree_map; 'auto' = "
             "pick via the cost-model autotuner, see --autotune-trace)")
    g.add_argument(
        "--autotune-trace", default=None, metavar="PATH",
        help="span trace (JSONL from --trace-out or repro.autotune.profile) "
             "the '--bucket-bytes auto' cost model is fitted from; default "
             "$REPRO_AUTOTUNE_TRACE")
    g.add_argument(
        "--agg-wire-bits", "--wire-bits", dest="agg_wire_bits", type=int,
        default=32, choices=[8, 16, 32],
        help="wire element width for the integer collective")
    g.add_argument(
        "--agg-pod-wire-bits", "--pod-wire-bits", dest="agg_pod_wire_bits",
        type=int, default=None, choices=[8, 16, 32],
        help="narrower wire for the cross-pod hop on hierarchical meshes "
             "(default: --agg-wire-bits)")
    g.add_argument(
        "--agg-fmt", default="fp32", choices=["fp32", "fp16", "bf16"],
        help="packed floating-point format of the aggregated values")
    g.add_argument(
        "--agg-block", type=int, default=DEFAULT_BLOCK, metavar="N",
        help="FPISA block size (elements sharing one exponent)")
    return g


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StrategySpec:
    """One registered aggregation strategy with its capability flags.

    ``fn`` / ``stacked_fn`` take ``(x, axes, cfg)``; ``hierarchical_fn`` takes
    ``(x, data_axis, pod_axis, cfg)``. The ``*_phases`` hooks are optional
    split-phase pipeline factories consumed by ``core/bucketer.py`` for
    double-buffered dispatch — a strategy without them streams through the
    one-shot path with the same interleaving."""

    name: str
    fn: Callable
    stacked_fn: Callable | None = None
    hierarchical_fn: Callable | None = None
    # capability flags (validated once, at Aggregator construction)
    supports_chunking: bool = True
    # chunking is an identity for elementwise strategies (native float psum):
    # the chunked scan is skipped rather than paid
    chunk_noop: bool = False
    requires_host_callback: bool = False
    # optional config validator: raises on combinations the strategy cannot
    # honor (e.g. switch_emu's numpy dataplane is fp32-only)
    validate: Callable | None = None
    # bucketer staging dtype: (cfg, dtype_group_name) -> jnp dtype the bucket
    # buffer is assembled in (defaults to float32)
    stage_dtype: Callable | None = None
    # split-phase pipeline factories for the bucketer's double-buffering:
    #   flat_phases(axes, cfg, backend)                      -> (enc, coll, fin)
    #   hier_phases(data_axis, pod_axis, cfg, backend, stripe) -> (enc, coll, fin)
    #   stacked_phases(axes, cfg, backend, k)                -> (enc, coll, fin)
    flat_phases: Callable | None = None
    hier_phases: Callable | None = None
    stacked_phases: Callable | None = None
    description: str = ""

    @property
    def supports_stacking(self) -> bool:
        return self.stacked_fn is not None

    @property
    def supports_hierarchical(self) -> bool:
        return self.hierarchical_fn is not None


_REGISTRY: dict[str, StrategySpec] = {}


def register_strategy(name: str, *, stacked: Callable | None = None,
                      hierarchical: Callable | None = None,
                      supports_chunking: bool = True, chunk_noop: bool = False,
                      requires_host_callback: bool = False,
                      validate: Callable | None = None,
                      stage_dtype: Callable | None = None,
                      flat_phases: Callable | None = None,
                      hier_phases: Callable | None = None,
                      stacked_phases: Callable | None = None,
                      description: str = "", overwrite: bool = False):
    """Decorator registering ``fn(x, axes, cfg)`` as strategy ``name``.

        @register_strategy("netfc", stacked=netfc_stacked,
                           supports_chunking=False,
                           description="table-lookup FP add")
        def netfc_allreduce(x, axes, cfg): ...

    Also usable as a plain call: ``register_strategy("native", ...)(fn)``.
    Re-registering an existing name requires ``overwrite=True`` (guards
    against two plugins silently colliding)."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY and not overwrite:
            raise ValueError(
                f"aggregation strategy {name!r} is already registered "
                f"(pass overwrite=True to replace it)")
        _REGISTRY[name] = StrategySpec(
            name=name, fn=fn, stacked_fn=stacked, hierarchical_fn=hierarchical,
            supports_chunking=supports_chunking, chunk_noop=chunk_noop,
            requires_host_callback=requires_host_callback, validate=validate,
            stage_dtype=stage_dtype, flat_phases=flat_phases,
            hier_phases=hier_phases, stacked_phases=stacked_phases,
            description=description or (fn.__doc__ or "").split("\n")[0])
        return fn

    return deco


def unregister_strategy(name: str) -> None:
    """Remove a registered strategy (test/plugin teardown)."""
    _REGISTRY.pop(name, None)


def _ensure_builtin() -> None:
    # the built-in strategies live in repro.core.allreduce, which registers
    # them at import time; importing lazily here breaks the module cycle
    # (allreduce imports this module for AggConfig + the registry)
    if "fpisa" not in _REGISTRY:
        from repro.core import allreduce  # noqa: F401


def available_strategies() -> tuple[str, ...]:
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))


def get_strategy(name: str) -> StrategySpec:
    """Look up a strategy; unknown names fail with the registered options and
    the nearest match instead of a bare KeyError."""
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregation strategy {name!r}; registered strategies: "
            f"{', '.join(sorted(_REGISTRY))}"
            f"{_did_you_mean(name, sorted(_REGISTRY))}") from None


# ---------------------------------------------------------------------------
# dispatch (internal — consumers go through Aggregator; the deprecation shims
# in repro.core.allreduce also land here)
# ---------------------------------------------------------------------------


def _dispatch(x: jax.Array, axes: tuple, cfg: AggConfig) -> jax.Array:
    """Single-array dispatch: chunked scan -> hierarchical -> flat."""
    spec = get_strategy(cfg.strategy)
    if cfg.chunk_elems and not spec.chunk_noop and x.size > cfg.chunk_elems:
        if not spec.supports_chunking:
            raise ValueError(
                f"strategy {cfg.strategy!r} does not support chunk_elems")
        return _chunked(x, axes, cfg)
    if len(axes) == 2 and spec.hierarchical_fn is not None:
        pod_axis, data_axis = axes[0], axes[1]
        return spec.hierarchical_fn(x, data_axis, pod_axis, cfg)
    return spec.fn(x, axes, cfg)


def _dispatch_stacked(x: jax.Array, axes: tuple, cfg: AggConfig) -> jax.Array:
    """Stacked (leading logical-worker axis) dispatch."""
    spec = get_strategy(cfg.strategy)
    if cfg.chunk_elems:
        raise NotImplementedError(
            "chunk_elems is not supported with stacked (logical-worker) "
            "aggregation; use bucket_bytes to bound transient memory instead")
    if spec.stacked_fn is None:
        raise ValueError(
            f"strategy {cfg.strategy!r} does not support stacked "
            f"(logical-worker) aggregation")
    return spec.stacked_fn(x, axes, cfg)


def _chunked(x: jax.Array, axes: tuple, cfg: AggConfig) -> jax.Array:
    """Stream the aggregation through fixed-size chunks (lax.scan) so the
    integer planes of only ONE chunk are live at a time."""
    inner = dataclasses.replace(cfg, chunk_elems=0)
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % cfg.chunk_elems
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, cfg.chunk_elems)

    def body(_, c):
        return None, _dispatch(c, axes, inner).astype(orig_dtype)

    _, out = lax.scan(body, None, chunks)
    out = out.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(orig_shape).astype(orig_dtype)


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------


class Aggregator:
    """The one aggregation entry point (module doc).

    Constructed OUTSIDE the traced function (validation is Python-level), its
    two methods run INSIDE ``shard_map`` over ``axis_names``:

        agg = Aggregator(AggConfig(strategy="fpisa"), ("pod", "data"))
        ...
        y    = agg.allreduce(x)        # one array
        tree = agg.allreduce_tree(g)   # a gradient pytree (bucketed when
                                       # cfg.bucket_bytes is set)

    ``stacked=True`` selects logical-worker mode: every input carries a
    leading worker axis and the reduction runs over that axis plus the mesh
    axes through the strategy's stacked variant (elastic fault tolerance,
    DESIGN.md §8).

    All capability checks happen here, once: unknown strategy/backend names
    (with the valid options and nearest match), chunking with stacking or
    with a strategy that cannot chunk, stacking without a stacked variant,
    and per-strategy config validation (e.g. ``switch_emu`` is fp32-only).
    """

    def __init__(self, cfg: AggConfig, axis_names: Sequence[str] | str, *,
                 stacked: bool = False):
        if isinstance(axis_names, str):
            axis_names = (axis_names,)
        self.cfg = cfg
        self.axes = tuple(axis_names)
        self.stacked = bool(stacked)
        self.spec = get_strategy(cfg.strategy)
        self.backend = resolve_backend(cfg.backend)

        if self.stacked and not self.spec.supports_stacking:
            raise ValueError(
                f"strategy {cfg.strategy!r} does not support stacked "
                f"(logical-worker) aggregation; stacked-capable strategies: "
                f"{', '.join(s for s in available_strategies() if get_strategy(s).supports_stacking)}")
        if self.stacked and cfg.chunk_elems:
            raise ValueError(
                "chunk_elems is not supported with stacked (logical-worker) "
                "aggregation; use bucket_bytes to bound transient memory "
                "instead")
        if cfg.chunk_elems and not (self.spec.supports_chunking
                                    or self.spec.chunk_noop):
            raise ValueError(
                f"strategy {cfg.strategy!r} does not support chunk_elems "
                f"(set chunk_elems=0)")
        if cfg.bucket_bytes and cfg.chunk_elems \
                and cfg.chunk_elems % cfg.block:
            raise ValueError(
                f"bucket_bytes with chunk_elems requires chunk_elems to be a "
                f"multiple of block={cfg.block} for bit-identity "
                f"(got chunk_elems={cfg.chunk_elems}; see core/bucketer.py)")
        if self.spec.validate is not None:
            self.spec.validate(cfg)

    # -- introspection ----------------------------------------------------

    @property
    def strategy(self) -> str:
        return self.spec.name

    @property
    def requires_host_callback(self) -> bool:
        """True when the strategy round-trips through a host callback
        (``jax.pure_callback``) — such strategies need a fully-manual
        (data-only) mesh. Exposed for consumers picking a mesh; the elastic
        controller's data-only re-mesh and the serving engine's 1-D data
        mesh satisfy the constraint by construction."""
        return self.spec.requires_host_callback

    def __repr__(self) -> str:
        return (f"Aggregator(strategy={self.spec.name!r}, "
                f"backend={self.backend!r}, axes={self.axes}, "
                f"stacked={self.stacked}, "
                f"chunk_elems={self.cfg.chunk_elems}, "
                f"bucket_bytes={self.cfg.bucket_bytes})")

    # -- the two calls ----------------------------------------------------

    def allreduce(self, x: jax.Array) -> jax.Array:
        """Aggregate one array over the configured axes (leading
        logical-worker axis first when ``stacked``)."""
        with _trace.span("agg.allreduce", strategy=self.spec.name,
                         backend=self.backend, stacked=self.stacked) as sp:
            if self.stacked:
                out = _dispatch_stacked(x, self.axes, self.cfg)
            else:
                out = _dispatch(x, self.axes, self.cfg)
            sp.sync(out)
        return out

    def allreduce_tree(self, tree):
        """Aggregate every leaf of a gradient pytree.

        With ``cfg.bucket_bytes`` set, the whole pytree is flattened into
        fixed-size block-aligned wire buckets and streamed double-buffered
        (core/bucketer.py) — bit-identical to the per-leaf path but with the
        per-collective encode/decode overhead amortized over whole buckets.
        Otherwise: per-leaf tree_map (XLA's latency-hiding scheduler still
        overlaps the independent per-leaf collectives with other work)."""
        with _trace.span("agg.allreduce_tree", strategy=self.spec.name,
                         backend=self.backend, stacked=self.stacked,
                         bucket_bytes=self.cfg.bucket_bytes) as sp:
            if self.cfg.bucket_bytes:
                from repro.core import bucketer

                if self.stacked:
                    out = bucketer.bucketed_stacked_allreduce_tree(
                        tree, self.axes, self.cfg)
                else:
                    out = bucketer.bucketed_allreduce_tree(
                        tree, self.axes, self.cfg)
            else:
                out = jax.tree_util.tree_map(self.allreduce, tree)
            sp.sync(out)
        return out
