"""Shared bit-level numerics for FPISA.

Everything here is pure jnp, shape-polymorphic, and safe inside Pallas kernel
bodies (interpret or compiled) as well as in plain jitted code.

FP32 layout reminder: [sign:1][exp:8 bias 127][mantissa:23 implied-1].
FPISA stores a value as (exp: int32 in [0,255], man: int32 two's-complement,
24-bit magnitude right-aligned => 7 headroom bits + sign bit).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

FP32_EXP_BITS = 8
FP32_MAN_BITS = 23
FP32_EXP_BIAS = 127
FP32_EXP_MASK = (1 << FP32_EXP_BITS) - 1          # 0xFF
FP32_MAN_MASK = (1 << FP32_MAN_BITS) - 1          # 0x7FFFFF
FP32_IMPLIED_ONE = 1 << FP32_MAN_BITS             # 0x800000
# Headroom bits left of the 24-bit magnitude in an int32 register (excl. sign).
FP32_HEADROOM = 31 - (FP32_MAN_BITS + 1)          # 7


@dataclasses.dataclass(frozen=True)
class FpFormat:
    """A packed IEEE-like floating point format handled by FPISA."""

    name: str
    exp_bits: int
    man_bits: int
    # register width used for the signed mantissa plane
    reg_bits: int = 32

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def exp_mask(self) -> int:
        return (1 << self.exp_bits) - 1

    @property
    def man_mask(self) -> int:
        return (1 << self.man_bits) - 1

    @property
    def implied_one(self) -> int:
        return 1 << self.man_bits

    @property
    def headroom(self) -> int:
        # sign bit occupies the top of the register
        return self.reg_bits - 1 - (self.man_bits + 1)

    @property
    def total_bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits


FP32 = FpFormat("fp32", exp_bits=8, man_bits=23)
FP16 = FpFormat("fp16", exp_bits=5, man_bits=10)
BF16 = FpFormat("bf16", exp_bits=8, man_bits=7)

FORMATS = {f.name: f for f in (FP32, FP16, BF16)}


def bitcast_f32_to_i32(x):
    return jnp.asarray(x, jnp.float32).view(jnp.int32)


def bitcast_i32_to_f32(x):
    return jnp.asarray(x, jnp.int32).view(jnp.float32)


def clz32(x):
    """Branchless count-leading-zeros for int32/uint32 (vectorized).

    This is the software analogue of the paper's TCAM longest-prefix-match
    table (Fig. 5): a 5-step binary search over the bit positions.
    Returns 32 for x == 0.
    """
    x = jnp.asarray(x).astype(jnp.uint32)
    n = jnp.full(x.shape, 0, jnp.int32)
    for shift in (16, 8, 4, 2, 1):
        big = (x >> shift) != 0
        n = jnp.where(big, n + shift, n)
        x = jnp.where(big, x >> shift, x)
    # x now holds the top set bit (0 or 1)
    n = jnp.where(x != 0, n, -1)  # n = floor(log2(x)); -1 for zero
    return jnp.asarray(31 - n, jnp.int32)  # clz; 32 when x == 0


def floor_log2_u32(x):
    """floor(log2(x)) for x > 0 (int32 result); -1 for x == 0."""
    return jnp.asarray(31, jnp.int32) - clz32(x)


def arshift(x, s):
    """Arithmetic right shift with clamped, possibly-vector shift distance.

    Shifting an int32 by >= 32 is UB in XLA; clamp to 31 which preserves the
    round-toward-negative-infinity semantics of two's-complement shifts
    (positive -> 0, negative -> -1).
    """
    s = jnp.clip(jnp.asarray(s, jnp.int32), 0, 31)
    return jnp.right_shift(jnp.asarray(x, jnp.int32), s)


def lshift(x, s):
    s = jnp.clip(jnp.asarray(s, jnp.int32), 0, 31)
    return jnp.left_shift(jnp.asarray(x, jnp.int32), s)


def required_preshift(num_workers: int, fmt: FpFormat = FP32) -> int:
    """Right-shift applied to every aligned mantissa before an integer
    reduction over `num_workers` contributions so the int32 accumulator can
    never overflow: |m| < 2^(man_bits+1), sum < W * 2^(man_bits+1-s) must be
    < 2^(reg_bits-1)."""
    import math

    need = max(0, math.ceil(math.log2(max(num_workers, 1))) - fmt.headroom)
    return need
