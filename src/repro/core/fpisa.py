"""FPISA: floating-point arithmetic on integer registers (paper core).

Implements, in pure JAX:

* ``encode`` / ``decode``      — FP <-> (exponent, signed two's-complement
                                 mantissa) "integer plane" representation (Fig. 3).
* ``fpisa_add_full``           — the full FPISA addition (requires the paper's
                                 RSAW shift-and-add extension on a switch; free
                                 on a TPU VPU). Aligns whichever operand is
                                 smaller (Sec. 3.2).
* ``fpisa_a_add``              — FPISA-A: only the *incoming* mantissa is ever
                                 shifted; left-shift into headroom when the
                                 incoming exponent is larger by <= headroom,
                                 overwrite beyond that (Sec. 4.3).
* ``renormalize``              — delayed renormalization: CLZ + shift + exponent
                                 fixup + pack (Sec. 3.2 "Renormalize and Assemble").
* ``fpisa_sum_sequential``     — scan-based accumulation over a worker axis;
                                 bit-faithful to the switch's packet-arrival
                                 semantics (the paper's own accuracy eval uses
                                 an equivalent software library).
* ``block_encode`` / ``block_decode`` — block-floating-point planes used by the
                                 production integer-domain all-reduce
                                 (core/allreduce.py): one shared exponent per
                                 block, mantissas aligned to it with a
                                 worker-count-dependent pre-shift so an int32
                                 reduction can never overflow.

All ops are elementwise/vectorized and usable inside Pallas kernel bodies.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import numerics as nx
from repro.core.numerics import BF16, FP16, FP32, FORMATS, FpFormat

__all__ = [
    "FP32",
    "FP16",
    "BF16",
    "FORMATS",
    "FpFormat",
    "Planes",
    "encode",
    "decode",
    "renormalize",
    "fpisa_add_full",
    "fpisa_a_add",
    "fpisa_sum_sequential",
    "block_encode",
    "block_decode",
    "block_max_exponent",
]


class Planes(NamedTuple):
    """Decoupled integer representation of an FP tensor (Fig. 3)."""

    exp: jax.Array  # int32, biased exponent in [0, 2^exp_bits - 1]
    man: jax.Array  # int32, two's-complement signed mantissa (implied 1 made explicit)


# ---------------------------------------------------------------------------
# Packed-bits extraction per format
# ---------------------------------------------------------------------------

_PACKED_DTYPE = {"fp32": jnp.float32, "fp16": jnp.float16, "bf16": jnp.bfloat16}
_BITS_DTYPE = {"fp32": jnp.int32, "fp16": jnp.int16, "bf16": jnp.int16}


def _to_bits(x: jax.Array, fmt: FpFormat) -> jax.Array:
    """Bitcast packed FP values to an int32 tensor holding the raw bits."""
    packed = jnp.asarray(x, _PACKED_DTYPE[fmt.name])
    bits = packed.view(_BITS_DTYPE[fmt.name])
    if fmt.name != "fp32":
        bits = bits.astype(jnp.int32) & 0xFFFF
    return bits.astype(jnp.int32)


def _from_bits(bits: jax.Array, fmt: FpFormat) -> jax.Array:
    if fmt.name == "fp32":
        return bits.astype(jnp.int32).view(jnp.float32)
    b16 = bits.astype(jnp.uint16).view(jnp.int16)
    return b16.view(_PACKED_DTYPE[fmt.name])


def encode(x: jax.Array, fmt: FpFormat = FP32) -> Planes:
    """Extract (exp, signed mantissa) planes from packed FP values.

    The implied leading 1 is made explicit; the sign is folded into the
    mantissa as two's complement (paper Sec. 3.1). Denormals flush to zero;
    NaN/Inf are not representable in-switch and are clamped to the largest
    finite value of the format (documented deviation — the paper assumes
    finite inputs).
    """
    bits = _to_bits(x, fmt)
    total = fmt.total_bits
    sign = (bits >> (total - 1)) & 1
    exp = (bits >> fmt.man_bits) & fmt.exp_mask
    man = bits & fmt.man_mask

    is_denorm = exp == 0
    is_special = exp == fmt.exp_mask  # inf / nan
    # clamp specials to max finite
    exp = jnp.where(is_special, fmt.exp_mask - 1, exp)
    man = jnp.where(is_special, fmt.man_mask, man)

    mag = jnp.where(is_denorm, 0, man | fmt.implied_one).astype(jnp.int32)
    exp = jnp.where(is_denorm, 0, exp).astype(jnp.int32)
    signed = jnp.where(sign == 1, -mag, mag).astype(jnp.int32)
    return Planes(exp=exp, man=signed)


def renormalize(planes: Planes, fmt: FpFormat = FP32) -> jax.Array:
    """Delayed renormalization + assembly back to the packed format.

    Semantics follow the paper: two's-complement arithmetic shifts, i.e.
    round-toward-negative-infinity (Appendix A.1); exponent overflow clamps to
    +/-inf; underflow flushes to zero.
    """
    e, m = jnp.asarray(planes.exp, jnp.int32), jnp.asarray(planes.man, jnp.int32)
    neg = m < 0
    mag = jnp.abs(m).astype(jnp.uint32)

    k = nx.floor_log2_u32(mag)  # position of leading 1; -1 when zero
    shift = k - fmt.man_bits  # >0: too big, shift right; <0: shift left
    # Arithmetic shift on the *signed* mantissa implements round-to-neg-inf.
    m_shifted = jnp.where(shift >= 0, nx.arshift(m, shift), nx.lshift(m, -shift))
    # Rounding toward -inf can carry the magnitude up to exactly 2^(man_bits+1)
    # (negative inputs only); fix up with one extra exact shift.
    mag2 = jnp.abs(m_shifted).astype(jnp.uint32)
    carry = (mag2 >> jnp.uint32(fmt.man_bits + 1)) != 0
    m_shifted = jnp.where(carry, nx.arshift(m_shifted, 1), m_shifted)
    shift = shift + carry.astype(jnp.int32)

    new_e = e + shift
    man_bits_out = jnp.abs(m_shifted).astype(jnp.int32) & fmt.man_mask

    zero = m == 0
    underflow = new_e <= 0
    overflow = new_e >= fmt.exp_mask

    exp_out = jnp.clip(new_e, 0, fmt.exp_mask)
    exp_out = jnp.where(zero | underflow, 0, exp_out)
    exp_out = jnp.where(overflow, fmt.exp_mask, exp_out)
    man_out = jnp.where(zero | underflow | overflow, 0, man_bits_out)

    total = fmt.total_bits
    bits = (
        (neg.astype(jnp.int32) << (total - 1))
        | (exp_out << fmt.man_bits)
        | man_out
    )
    # zero: keep signless +0 (switch register cannot hold -0 distinctly)
    bits = jnp.where(zero, 0, bits)
    return _from_bits(bits, fmt)


def decode(planes: Planes, fmt: FpFormat = FP32) -> jax.Array:
    """Alias for renormalize — kept for symmetry with encode."""
    return renormalize(planes, fmt)


# ---------------------------------------------------------------------------
# Accumulator updates
# ---------------------------------------------------------------------------


class AddStats(NamedTuple):
    overwrite: jax.Array  # bool: FPISA-A dropped the old accumulator value
    overflow: jax.Array  # bool: int32 register overflow (headroom exceeded)


def _overflowed(a: jax.Array, b: jax.Array, s: jax.Array) -> jax.Array:
    """Signed-add overflow detect for s = a + b (int32, two's complement)."""
    return ((a ^ s) & (b ^ s)) < 0


def fpisa_add_full(acc: Planes, inp: Planes, fmt: FpFormat = FP32):
    """Full FPISA addition (needs the RSAW extension on a switch).

    Whichever operand has the smaller exponent gets right-shifted; the result
    keeps the larger exponent (paper Sec. 3.2, Fig. 4). Returns (Planes, AddStats).
    """
    d = inp.exp - acc.exp
    # d <= 0: incoming is smaller-or-equal -> shift incoming right.
    m_le = acc.man + nx.arshift(inp.man, -d)
    # d > 0: stored value smaller -> shift *stored* mantissa right (RSAW).
    m_gt = nx.arshift(acc.man, d) + inp.man

    le = d <= 0
    shifted_in = jnp.where(le, nx.arshift(inp.man, -d), inp.man)
    shifted_acc = jnp.where(le, acc.man, nx.arshift(acc.man, d))
    new_m = jnp.where(le, m_le, m_gt)
    new_e = jnp.where(le, acc.exp, inp.exp)
    overflow = _overflowed(shifted_acc, shifted_in, new_m)
    stats = AddStats(overwrite=jnp.zeros_like(overflow), overflow=overflow)
    return Planes(exp=new_e, man=new_m), stats


def fpisa_a_add(acc: Planes, inp: Planes, fmt: FpFormat = FP32):
    """FPISA-A addition: deployable on unmodified Tofino (paper Sec. 4.3).

    Only the incoming mantissa is ever shifted:
      * d <= 0            : right-shift incoming (identical to full FPISA);
      * 0 < d <= headroom : left-shift incoming into the headroom bits,
                            accumulator exponent unchanged (denormalized);
      * d > headroom      : overwrite the accumulator with the incoming value
                            ("overwrite" error, bounded; rare for gradients).
    """
    d = inp.exp - acc.exp
    h = fmt.headroom

    right = acc.man + nx.arshift(inp.man, -d)
    left = acc.man + nx.lshift(inp.man, d)

    use_right = d <= 0
    use_left = (d > 0) & (d <= h)
    use_over = d > h

    new_m = jnp.where(use_right, right, jnp.where(use_left, left, inp.man))
    new_e = jnp.where(use_over, inp.exp, acc.exp)

    shifted_in = jnp.where(use_right, nx.arshift(inp.man, -d), nx.lshift(inp.man, d))
    overflow = jnp.where(use_over, False, _overflowed(acc.man, shifted_in, new_m))
    # Overwriting a zero accumulator is the normal "first write", not an error.
    overwrite = use_over & (acc.man != 0)
    return Planes(exp=new_e, man=new_m), AddStats(overwrite=overwrite, overflow=overflow)


def fpisa_sum_sequential(
    values: jax.Array,
    fmt: FpFormat = FP32,
    variant: str = "fpisa_a",
    return_stats: bool = False,
):
    """Aggregate ``values`` along axis 0 with switch-arrival semantics.

    ``values``: (num_workers, ...) packed FP tensor. Worker 0 arrives first.
    This is the paper's software-library equivalent used for all accuracy /
    convergence experiments (Sec. 5.2.1-5.2.2). Returns the packed FP result
    (and summed event counts when ``return_stats``).
    """
    add = fpisa_a_add if variant == "fpisa_a" else fpisa_add_full
    planes = encode(values, fmt)

    def body(carry, x):
        acc, n_over, n_ovf = carry
        new_acc, st = add(acc, Planes(*x), fmt)
        return (
            new_acc,
            n_over + jnp.sum(st.overwrite),
            n_ovf + jnp.sum(st.overflow),
        ), None

    zero = Planes(
        exp=jnp.zeros(values.shape[1:], jnp.int32),
        man=jnp.zeros(values.shape[1:], jnp.int32),
    )
    (acc, n_over, n_ovf), _ = jax.lax.scan(
        body, (zero, jnp.int32(0), jnp.int32(0)), (planes.exp, planes.man)
    )
    out = renormalize(acc, fmt)
    if return_stats:
        return out, {"overwrite": n_over, "overflow": n_ovf}
    return out


# ---------------------------------------------------------------------------
# Block planes for the production integer-domain all-reduce
# ---------------------------------------------------------------------------


def block_max_exponent(exp: jax.Array, block: int) -> jax.Array:
    """Per-block max of the exponent plane. exp: (..., N) with N % block == 0."""
    shp = exp.shape
    e = exp.reshape(shp[:-1] + (shp[-1] // block, block))
    return jnp.max(e, axis=-1)


def block_encode(
    x: jax.Array,
    block_exp: jax.Array,
    block: int,
    preshift: int,
    fmt: FpFormat = FP32,
) -> jax.Array:
    """Align mantissas of ``x`` to the (globally-maxed) block exponent.

    ``block_exp``: (..., N // block) int32, already maxed across workers.
    Result: int32 mantissa plane at scale 2^(block_exp - bias - man_bits + preshift),
    i.e. each element's true value is man * 2^(block_exp - bias - man_bits + preshift).
    The right-shift truncation implements the same round-toward-neg-inf
    semantics as the switch registers.
    """
    planes = encode(x, fmt)
    be = jnp.repeat(block_exp, block, axis=-1)
    shift = (be - planes.exp) + preshift
    return nx.arshift(planes.man, shift)


def block_decode(
    man_sum: jax.Array,
    block_exp: jax.Array,
    block: int,
    preshift: int,
    fmt: FpFormat = FP32,
) -> jax.Array:
    """Renormalize summed block mantissas back to packed FP (delayed renorm)."""
    be = jnp.repeat(block_exp, block, axis=-1)
    return renormalize(Planes(exp=be + preshift, man=man_sum), fmt)
