"""Pallas TPU kernels: FPISA extract + align (the pre-collective transform).

This is the compute hot-spot the paper moves off the end-host CPU (Sec. 4.1's
endianness/quantization overhead, Fig. 6/10): converting a gradient stream
into switch-register form at line rate. On TPU the equivalent requirement is
that the transform must run at HBM bandwidth so the collective — not the
transform — is the bottleneck. Both kernels are single-pass elementwise/
row-reduce VPU work tiled for VMEM:

  extract: f32 tile -> (exp, signed mantissa, per-row max-exp)   [1R + 2W + R/B]
  align:   (exp, man, global block exp) -> aligned mantissa      [2R + 1W]

Tiling: inputs are reshaped to (R, B) with B = the FPISA block size (a
multiple of 128 lanes); a grid step processes a (TILE_R, B) tile held in VMEM.
All integer ops are 32-bit VPU ops; there is no MXU involvement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fpisa
from repro.core import numerics as nx

# 256 rows x 256-wide blocks x 4 B = 256 KiB per operand tile; the extract
# kernel holds ~4 operands in VMEM (x, exp, man, bmax) ~= 1 MiB << 16 MiB VMEM.
TILE_R = 256


def _extract_kernel(x_ref, exp_ref, man_ref, bmax_ref, *, fmt: fpisa.FpFormat):
    x = x_ref[...]
    planes = fpisa.encode(x, fmt)
    exp_ref[...] = planes.exp
    man_ref[...] = planes.man
    bmax_ref[...] = jnp.max(planes.exp, axis=-1, keepdims=True)


def _align_kernel(exp_ref, man_ref, bmax_ref, out_ref, *, preshift: int):
    shift = (bmax_ref[...] - exp_ref[...]) + preshift  # bmax broadcasts (TILE_R, 1)
    out_ref[...] = nx.arshift(man_ref[...], shift)


@functools.partial(jax.jit, static_argnames=("fmt_name", "interpret"))
def fpisa_extract(x: jax.Array, fmt_name: str = "fp32", interpret: bool = False):
    """x: (R, B) packed FP32 -> (exp i32 (R,B), man i32 (R,B), bmax i32 (R,))."""
    fmt = fpisa.FORMATS[fmt_name]
    r, b = x.shape
    tile_r = min(TILE_R, r)
    grid = (pl.cdiv(r, tile_r),)
    exp, man, bmax = pl.pallas_call(
        functools.partial(_extract_kernel, fmt=fmt),
        grid=grid,
        in_specs=[pl.BlockSpec((tile_r, b), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
            pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
            pl.BlockSpec((tile_r, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, b), jnp.int32),
            jax.ShapeDtypeStruct((r, b), jnp.int32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    return exp, man, bmax[:, 0]


@functools.partial(jax.jit, static_argnames=("preshift", "interpret"))
def fpisa_align(
    exp: jax.Array,
    man: jax.Array,
    bmax: jax.Array,
    preshift: int = 0,
    interpret: bool = False,
):
    """Align mantissas to the (already cross-worker-maxed) block exponent."""
    r, b = man.shape
    tile_r = min(TILE_R, r)
    grid = (pl.cdiv(r, tile_r),)
    return pl.pallas_call(
        functools.partial(_align_kernel, preshift=preshift),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
            pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
            pl.BlockSpec((tile_r, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, b), jnp.int32),
        interpret=interpret,
    )(exp, man, bmax[:, None])
