# FPISA Pallas kernel package: the pre/post-collective transform hot path
# (fpisa_fused.py single-pass kernels + two-pass reference kernels), their
# jit'd wrappers (ops.py) and pure-jnp oracles (ref.py). See README.md here
# for the pipeline diagram, backend flag, and VMEM tiling budget.
