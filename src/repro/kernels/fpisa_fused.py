"""Pallas TPU kernels: fused single-pass FPISA encode->align and decode.

The two-kernel pipeline in ``fpisa_encode.py`` (extract, then align) round-trips
the intermediate (exp, man) planes through HBM between the passes: 1R + 3W for
extract plus 3R + 1W for align — 8 plane-sized HBM transfers to produce one
aligned mantissa plane. That is exactly the "expensive workaround" shape the
paper attributes to end-host conversion (Sec. 4.1): the transform, not the
collective, becomes the bottleneck. These kernels collapse the hot path:

  fused_encode_align : f32 tile -> (locally-aligned int32 mantissa plane,
                       per-block max exponent).  ONE read of x, ONE write of
                       man (+ R ints of bmax); the (exp, man) planes live only
                       in VMEM/registers inside the tile pass.
  fused_decode       : (summed mantissa plane [any wire width], block exps) ->
                       packed FP.  Folds ``block_decode``'s exponent repeat,
                       wire-dtype upcast and renormalize into one tile pass.

Alignment factorization
-----------------------
The collective needs mantissas aligned to the *cross-worker* block exponent,
which is only known after a ``pmax``. Instead of a second full pass over the
(exp, man) planes, ``fused_encode_align`` aligns to the *local* block max in
the same pass that extracts the planes. Because non-negative arithmetic right
shifts compose exactly ( (m >> a) >> b == m >> (a+b), both round toward -inf,
and the >=31 clamp saturates identically), the caller finishes alignment with
a cheap per-element shift by ``(global_bmax - local_bmax) + preshift`` — a
jnp op that XLA fuses with the wire-dtype cast — and the result is
bit-identical to the reference ``extract_ref`` + ``align_ref`` composition
against the global exponent.

VMEM budget: a (TILE_R, B) f32/int32 tile is TILE_R*B*4 bytes; the fused
encode kernel holds ~3 live tiles (x, man, plus encode temporaries) — at the
default TILE_R=256, B=512 worst case that is ~1.5 MiB << 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fpisa
from repro.core import numerics as nx
from repro.kernels.fpisa_encode import TILE_R

_PACKED_OUT = {"fp32": jnp.float32, "fp16": jnp.float16, "bf16": jnp.bfloat16}


def _fused_encode_align_kernel(x_ref, man_ref, bmax_ref, *, fmt: fpisa.FpFormat):
    x = x_ref[...]
    planes = fpisa.encode(x, fmt)
    bmax = jnp.max(planes.exp, axis=-1, keepdims=True)  # (TILE_R, 1)
    man_ref[...] = nx.arshift(planes.man, bmax - planes.exp)
    bmax_ref[...] = bmax


def _fused_decode_kernel(man_ref, bmax_ref, out_ref, *, preshift: int, fmt: fpisa.FpFormat):
    man = man_ref[...].astype(jnp.int32)  # upcast narrow wire dtypes in-VMEM
    e = jnp.broadcast_to(bmax_ref[...] + preshift, man.shape)
    out = fpisa.renormalize(fpisa.Planes(exp=e, man=man), fmt)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fmt_name", "interpret"))
def fused_encode_align(x: jax.Array, fmt_name: str = "fp32", interpret: bool = False):
    """x: (R, B) packed FP -> (man (R,B) i32 aligned to the LOCAL block max,
    bmax (R,) i32 local per-block max exponent).

    One HBM read of x, one HBM write of man; no intermediate plane traffic.
    Finish cross-worker alignment with ``nx.arshift(man, (global_bmax -
    bmax)[:, None] + preshift)`` after the bmax pmax.
    """
    fmt = fpisa.FORMATS[fmt_name]
    r, b = x.shape
    tile_r = min(TILE_R, r)
    grid = (pl.cdiv(r, tile_r),)
    man, bmax = pl.pallas_call(
        functools.partial(_fused_encode_align_kernel, fmt=fmt),
        grid=grid,
        in_specs=[pl.BlockSpec((tile_r, b), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
            pl.BlockSpec((tile_r, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, b), jnp.int32),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x)
    return man, bmax[:, 0]


@functools.partial(jax.jit, static_argnames=("preshift", "fmt_name", "interpret"))
def fused_decode(
    man_sum: jax.Array,
    bmax: jax.Array,
    preshift: int = 0,
    fmt_name: str = "fp32",
    interpret: bool = False,
):
    """(R,B) int aggregated mantissas (int32/int16/int8 wire) + (R,) block
    exps -> (R,B) packed FP. Single tile pass: upcast, repeat, renormalize."""
    fmt = fpisa.FORMATS[fmt_name]
    r, b = man_sum.shape
    tile_r = min(TILE_R, r)
    grid = (pl.cdiv(r, tile_r),)
    return pl.pallas_call(
        functools.partial(_fused_decode_kernel, preshift=preshift, fmt=fmt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
            pl.BlockSpec((tile_r, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, b), _PACKED_OUT[fmt_name]),
        interpret=interpret,
    )(man_sum, bmax[:, None])
