"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (shape/dtype
sweeps in tests/test_kernels.py). They intentionally reuse repro.core.fpisa —
the kernels must match the core semantics bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fpisa
from repro.core import numerics as nx


def extract_ref(x: jax.Array, fmt: fpisa.FpFormat = fpisa.FP32):
    """x: (R, B) packed FP -> (exp (R,B) i32, man (R,B) i32, bmax (R,) i32).

    bmax is the per-row (= per-block) max exponent — the quantity that gets
    pmax'd across workers before alignment.
    """
    planes = fpisa.encode(x, fmt)
    bmax = jnp.max(planes.exp, axis=-1)
    return planes.exp, planes.man, bmax


def align_ref(
    exp: jax.Array,
    man: jax.Array,
    bmax: jax.Array,
    preshift: int,
    fmt: fpisa.FpFormat = fpisa.FP32,
):
    """Shift mantissas to the shared block exponent: (R,B) i32 -> (R,B) i32."""
    shift = (bmax[:, None] - exp) + preshift
    return nx.arshift(man, shift)


def decode_ref(
    man_sum: jax.Array,
    bmax: jax.Array,
    preshift: int,
    fmt: fpisa.FpFormat = fpisa.FP32,
):
    """(R,B) i32 summed mantissas + (R,) block exp -> (R,B) packed FP."""
    e = jnp.broadcast_to(bmax[:, None] + preshift, man_sum.shape)
    return fpisa.renormalize(fpisa.Planes(exp=e, man=man_sum), fmt)


def fused_encode_align_ref(x: jax.Array, fmt: fpisa.FpFormat = fpisa.FP32):
    """Oracle for the fused single-pass kernel: x (R,B) packed FP ->
    (man (R,B) i32 aligned to the LOCAL per-block max, bmax (R,) i32).

    Defined as the extract_ref + align_ref composition with preshift=0 against
    the local bmax — the fused kernel must match it bit-for-bit; the residual
    cross-worker shift composes exactly on top (see fpisa_fused module doc).
    """
    exp, man, bmax = extract_ref(x, fmt)
    return align_ref(exp, man, bmax, 0, fmt), bmax


def fused_decode_ref(
    man_sum: jax.Array,
    bmax: jax.Array,
    preshift: int,
    fmt: fpisa.FpFormat = fpisa.FP32,
):
    """Oracle for fused_decode: identical to decode_ref plus the wire-dtype
    upcast the kernel performs in-VMEM."""
    return decode_ref(man_sum.astype(jnp.int32), bmax, preshift, fmt)


def accum_ref(x: jax.Array, variant: str = "fpisa_a", fmt: fpisa.FpFormat = fpisa.FP32):
    """Sequential switch-order accumulation. x: (W, R, B) -> (R, B) packed FP."""
    w = x.shape[0]
    return fpisa.fpisa_sum_sequential(x.reshape(w, -1), fmt, variant=variant).reshape(
        x.shape[1:]
    )
