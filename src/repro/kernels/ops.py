"""Public jit'd wrappers for the FPISA Pallas kernels.

On a CPU host (this container) the kernels execute in Pallas interpret mode —
the kernel bodies run exactly as written, validating the TPU code path; on a
real TPU backend the same calls compile to Mosaic. `use_pallas=False` routes
to the pure-jnp oracles (ref.py), which XLA fuses well — that is the default
inside the big jitted train step so the dry-run HLO stays portable, while the
kernels are exercised by tests/benchmarks and available for the TPU hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fpisa
from repro.kernels import ref
from repro.kernels.fpisa_accum import fpisa_accum
from repro.kernels.fpisa_decode import fpisa_decode
from repro.kernels.fpisa_encode import fpisa_align, fpisa_extract
from repro.kernels.fpisa_fused import fused_decode, fused_encode_align


def _interpret() -> bool:
    # Interpret everywhere except a real TPU backend: the kernel bodies run
    # exactly as written (bit-identical semantics), so non-TPU hosts — CPU
    # *and* GPU — validate the TPU code path instead of attempting a Mosaic
    # compile that cannot succeed off-TPU.
    return jax.default_backend() != "tpu"


def extract(x: jax.Array, fmt_name: str = "fp32", use_pallas: bool = True):
    if not use_pallas:
        return ref.extract_ref(x, fpisa.FORMATS[fmt_name])
    return fpisa_extract(x, fmt_name=fmt_name, interpret=_interpret())


def align(exp, man, bmax, preshift: int = 0, use_pallas: bool = True):
    if not use_pallas:
        return ref.align_ref(exp, man, bmax, preshift)
    return fpisa_align(exp, man, bmax, preshift=preshift, interpret=_interpret())


def decode(man_sum, bmax, preshift: int = 0, fmt_name: str = "fp32", use_pallas: bool = True):
    if not use_pallas:
        return ref.decode_ref(man_sum, bmax, preshift)
    return fpisa_decode(man_sum, bmax, preshift=preshift, fmt_name=fmt_name, interpret=_interpret())


def accum(x, variant: str = "fpisa_a", fmt_name: str = "fp32", use_pallas: bool = True):
    if not use_pallas:
        return ref.accum_ref(x, variant=variant)
    return fpisa_accum(x, variant=variant, fmt_name=fmt_name, interpret=_interpret())


def encode_align(x, fmt_name: str = "fp32", use_pallas: bool = True):
    """Fused single-pass extract+align to the LOCAL block max (hot path)."""
    if not use_pallas:
        return ref.fused_encode_align_ref(x, fpisa.FORMATS[fmt_name])
    return fused_encode_align(x, fmt_name=fmt_name, interpret=_interpret())


def decode_fused(man_sum, bmax, preshift: int = 0, fmt_name: str = "fp32",
                 use_pallas: bool = True):
    """Fused decode accepting narrow wire dtypes (int8/int16/int32)."""
    if not use_pallas:
        return ref.fused_decode_ref(man_sum, bmax, preshift, fpisa.FORMATS[fmt_name])
    return fused_decode(man_sum, bmax, preshift=preshift, fmt_name=fmt_name,
                        interpret=_interpret())
