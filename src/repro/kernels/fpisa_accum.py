"""Pallas TPU kernel: sequential FPISA-A accumulation over a worker axis.

Bit-faithful to the switch's packet-arrival semantics (worker 0 first): this
is the in-VMEM equivalent of the MAU register pipeline of Fig. 2 — the
accumulator (exp, man) planes live in VMEM across the worker loop, exactly as
the switch registers persist across packets. Used by the accuracy/fidelity
benchmarks; the production all-reduce uses the associative block path instead.

Tiling: x is (W, R, B); a grid step owns a (TILE_R, B) slice of the register
file and loops over the W packets with `jax.lax.fori_loop`, so VMEM holds
W * TILE_R * B * 4 bytes of payload — the wrapper picks TILE_R to keep this
under ~4 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fpisa


def _accum_kernel(x_ref, out_ref, *, num_workers: int, variant: str, fmt: fpisa.FpFormat):
    add = fpisa.fpisa_a_add if variant == "fpisa_a" else fpisa.fpisa_add_full
    shape = x_ref.shape[1:]

    def body(i, acc):
        inp = fpisa.encode(x_ref[i], fmt)
        new, _ = add(fpisa.Planes(*acc), inp, fmt)
        return (new.exp, new.man)

    zero = (jnp.zeros(shape, jnp.int32), jnp.zeros(shape, jnp.int32))
    exp, man = jax.lax.fori_loop(0, num_workers, body, zero)
    out = fpisa.renormalize(fpisa.Planes(exp=exp, man=man), fmt)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("variant", "fmt_name", "interpret"))
def fpisa_accum(
    x: jax.Array,
    variant: str = "fpisa_a",
    fmt_name: str = "fp32",
    interpret: bool = False,
):
    """x: (W, R, B) packed FP32 -> (R, B) switch-order FPISA aggregate."""
    fmt = fpisa.FORMATS[fmt_name]
    w, r, b = x.shape
    # keep W * TILE_R * B * 4B <= ~4 MiB of VMEM for the payload tile
    budget_rows = max(8, (4 << 20) // max(1, w * b * 4))
    tile_r = min(r, budget_rows, 256)
    grid = (pl.cdiv(r, tile_r),)
    return pl.pallas_call(
        functools.partial(_accum_kernel, num_workers=w, variant=variant, fmt=fmt),
        grid=grid,
        in_specs=[pl.BlockSpec((w, tile_r, b), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, b), jnp.float32),
        interpret=interpret,
    )(x)
