"""Pallas TPU kernel: FPISA delayed renormalization + assembly (post-collective).

The egress-pipeline stage of the paper (Sec. 3.2 "Renormalize and Assemble"):
count leading zeros (the TCAM-LPM analogue is a 5-step branchless binary
search on the VPU), shift the two's-complement mantissa (round-to--inf),
adjust the exponent, pack to IEEE bits. One VMEM pass, no MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fpisa
from repro.kernels.fpisa_encode import TILE_R


def _decode_kernel(man_ref, bmax_ref, out_ref, *, preshift: int, fmt: fpisa.FpFormat):
    man = man_ref[...]
    e = jnp.broadcast_to(bmax_ref[...] + preshift, man.shape)  # (TILE_R,1) -> tile
    out = fpisa.renormalize(fpisa.Planes(exp=e, man=man), fmt)
    out_ref[...] = out.astype(jnp.float32) if fmt.name == "fp32" else out


@functools.partial(jax.jit, static_argnames=("preshift", "fmt_name", "interpret"))
def fpisa_decode(
    man_sum: jax.Array,
    bmax: jax.Array,
    preshift: int = 0,
    fmt_name: str = "fp32",
    interpret: bool = False,
):
    """(R,B) i32 aggregated mantissas + (R,) block exps -> (R,B) packed FP."""
    fmt = fpisa.FORMATS[fmt_name]
    r, b = man_sum.shape
    tile_r = min(TILE_R, r)
    grid = (pl.cdiv(r, tile_r),)
    out_dtype = {"fp32": jnp.float32, "fp16": jnp.float16, "bf16": jnp.bfloat16}[fmt_name]
    return pl.pallas_call(
        functools.partial(_decode_kernel, preshift=preshift, fmt=fmt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
            pl.BlockSpec((tile_r, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_r, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, b), out_dtype),
        interpret=interpret,
    )(man_sum, bmax[:, None])
