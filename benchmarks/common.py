"""Shared benchmark helpers. Every benchmark prints `name,us_per_call,derived`
CSV rows via `emit`, persists machine-readable results via `write_json`
(the perf-trajectory files the roadmap tracks), and times through `timed`/
`timeit` — the one place the perf_counter + block_until_ready discipline
lives (lint rule: timing-discipline), emitting tracer spans when tracing
is on."""
import json
import os
import platform
from time import perf_counter

import jax
import numpy as np

from repro import trace


def smoke() -> bool:
    """True when BENCH_SMOKE=1: benchmarks shrink to CI-smoke sizes so the
    whole suite runs in minutes (tests/test_benchmarks.py uses this to assert
    every module runs and every BENCH_*.json schema stays stable)."""
    return os.environ.get("BENCH_SMOKE", "0") not in ("0", "", "false")


def scaled(full, tiny):
    """``full`` normally, ``tiny`` under BENCH_SMOKE=1."""
    return tiny if smoke() else full


def timed(name, fn, *args, warmup=2, iters=5, **tags):
    """Time ``fn(*args)`` (mean seconds over ``iters`` after ``warmup``,
    each call blocked to readiness) and return ``(dt, out)``.

    The shared timing loop for every benchmark — no module hand-rolls its
    own perf_counter pairs (lint: timing-discipline). When the global tracer
    is enabled, each measured call also lands as a synced ``name`` span with
    ``tags``, so a traced benchmark run doubles as autotuner input."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = perf_counter()
    for _ in range(iters):
        with trace.span(name, **tags) as sp:
            out = jax.block_until_ready(fn(*args))
            sp.sync(out)
    dt = (perf_counter() - t0) / iters
    return dt, out


def timeit(fn, *args, warmup=2, iters=5):
    """Anonymous-span variant of :func:`timed` (legacy call sites)."""
    return timed("bench.timeit", fn, *args, warmup=warmup, iters=iters)


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}")


def results_path(name: str) -> str:
    """Where BENCH_<name>.json lands: $BENCH_DIR if set, else the CWD."""
    return os.path.join(os.environ.get("BENCH_DIR", "."), f"BENCH_{name}.json")


def write_json(name: str, payload: dict) -> str:
    """Write a benchmark's machine-readable results to BENCH_<name>.json.

    The payload is wrapped with enough provenance (backend, device count,
    host) for trajectory tooling to compare runs apples-to-apples."""
    doc = {
        "bench": name,
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "host": platform.node(),
        "results": _jsonable(payload),
    }
    path = results_path(name)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    emit(f"{name}.json", 0, f"wrote={path}")
    return path


def _jsonable(x):
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.ndarray):
        return _jsonable(x.tolist())
    if isinstance(x, (np.bool_,)):
        return bool(x)
    return x
