"""Shared benchmark helpers. Every benchmark prints `name,us_per_call,derived`
CSV rows via `emit`."""
import time

import jax
import numpy as np


def timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    dt = (time.perf_counter() - t0) / iters
    return dt, out


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}")
