"""Paper Fig. 11 — end-to-end training speedup of FPISA vs SwitchML across 7
DNN benchmarks, plus the repo's own end-to-end aggregation-step win from
block-aligned gradient bucketing (core/bucketer.py).

Two parts:

1. Link model (paper): MEASURED host transform cost per element combined with
   the paper's 100 Gbps line-rate model (2 communication rounds for SwitchML
   vs 1 for FPISA on the scale-factor exchange) over the 7 models' gradient
   sizes, for the CPU-constrained (2-core) case.
2. Bucketing (measured): aggregation step time of per-leaf ``allreduce_tree``
   vs the bucketed path on a ragged ~150-leaf gradient pytree shaped like a
   real LM's parameter list. Bucketed must be bit-identical AND no slower —
   both land in ``BENCH_fig11.json`` (the acceptance gate for ISSUE 3).
"""
import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, scaled, timed, write_json
from repro import compat
from repro.core.agg import AggConfig, Aggregator

MODELS = {  # gradient elements (paper's benchmarks, param counts)
    "MobileNetV2": 3.5e6, "GoogleNet": 6.6e6, "ResNet-50": 25.6e6,
    "VGG19": 143.7e6, "LSTM": 325e6, "BERT": 340e6, "DeepLight": 578e6,
}
LINK_ELEMS_PER_S = 100e9 / 8 / 4  # FP32 elements/s at 100 Gbps
CORES = 2

BUCKET_BYTES = 4 << 20


def _gradient_tree(rng, n_layers: int):
    """Ragged pytree shaped like an LM's parameter list: for each layer a
    large matmul leaf, a small matmul leaf, and a tiny (non-block-multiple)
    norm/bias vector — the per-leaf path's worst case."""
    tree = {}
    for i in range(n_layers):
        tree[f"l{i:03d}.ffn"] = (rng.standard_normal(16384) * 0.01)
        tree[f"l{i:03d}.attn"] = (rng.standard_normal(4096) * 0.01)
        tree[f"l{i:03d}.norm"] = (rng.standard_normal(777) * 0.01)
    return {k: jnp.asarray(v.astype(np.float32)) for k, v in tree.items()}


def bench_bucketing():
    """Measured per-leaf vs bucketed aggregation step time (+ parity bit)."""
    rng = np.random.default_rng(0)
    n_layers = scaled(64, 6)
    tree = _gradient_tree(rng, n_layers)
    n_leaves = len(tree)
    n_elems = sum(v.size for v in tree.values())
    mesh = compat.make_mesh((jax.device_count(),), ("data",))

    def make(bucket_bytes: int):
        agg = Aggregator(AggConfig(strategy="fpisa", backend="jnp",
                                   bucket_bytes=bucket_bytes), ("data",))
        return jax.jit(compat.shard_map(
            agg.allreduce_tree, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), tree),),
            out_specs=jax.tree.map(lambda _: P(), tree), check_vma=False))

    per_leaf_fn, bucketed_fn = make(0), make(BUCKET_BYTES)
    a, b = per_leaf_fn(tree), bucketed_fn(tree)
    bit_identical = all(
        bool(jnp.all(a[k].view(jnp.int32) == b[k].view(jnp.int32)))
        for k in tree)

    iters = scaled(10, 3)
    dt_leaf, _ = timed("fig11.per_leaf_step", per_leaf_fn, tree,
                       warmup=2, iters=iters, bucket_bytes=0)
    dt_buck, _ = timed("fig11.bucketed_step", bucketed_fn, tree,
                       warmup=2, iters=iters, bucket_bytes=BUCKET_BYTES)
    speedup = dt_leaf / dt_buck
    emit("fig11.bucketed_agg_step", dt_buck * 1e6,
         f"per_leaf_us={dt_leaf*1e6:.0f};speedup={speedup:.2f}x;"
         f"bit_identical={int(bit_identical)}")
    return {
        "n_leaves": n_leaves,
        "n_elems": int(n_elems),
        "bucket_bytes": BUCKET_BYTES,
        "per_leaf_us": dt_leaf * 1e6,
        "bucketed_us": dt_buck * 1e6,
        "speedup": speedup,
        "bucketed_le_per_leaf": bool(dt_buck <= dt_leaf),
        "bit_identical": bit_identical,
    }


def run():
    rng = np.random.default_rng(0)
    n = scaled(1 << 22, 1 << 16)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32) * 0.01)
    scale = jnp.float32(2.0 ** 20)
    sw = jax.jit(lambda v: (jnp.round(v * scale).astype(jnp.int32).astype(jnp.float32) / scale))
    dt_sw, _ = timed("fig11.switch_quantize", sw, x)
    sw_elems_per_core = n / dt_sw

    link = {}
    for name, g in MODELS.items():
        t_link = g / LINK_ELEMS_PER_S
        # SwitchML: host transform on CORES cores + extra scale-factor round
        # (paper: overlapped but serializing at chunk granularity ~ +5% wire)
        t_sw = max(g / (sw_elems_per_core * CORES), t_link * 1.05)
        t_fp = t_link  # FPISA: raw FP32 at line rate, no host transform
        emit(f"fig11.{name}", t_sw * 1e6, f"speedup={t_sw / t_fp:.3f}")
        link[name] = {"t_switchml_s": t_sw, "t_fpisa_s": t_fp,
                      "speedup": t_sw / t_fp}
    emit("fig11.paper_claim", 0, "up_to_1.859x_at_2cores")

    write_json("fig11", {"link_model": link, "bucketing": bench_bucketing()})
