"""Paper Fig. 11 — end-to-end training speedup of FPISA vs SwitchML across 7
DNN benchmarks. Without a 100 Gbps testbed we combine (a) MEASURED host
transform cost per element (fig10 paths) with (b) the paper's own link model
(100 Gbps line rate, 2 communication rounds for SwitchML vs 1 for FPISA on
the scale-factor exchange) over the 7 models' gradient sizes. Reported as
speedup in aggregation step time for the CPU-constrained (2-core) case."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import fpisa as F

MODELS = {  # gradient elements (paper's benchmarks, param counts)
    "MobileNetV2": 3.5e6, "GoogleNet": 6.6e6, "ResNet-50": 25.6e6,
    "VGG19": 143.7e6, "LSTM": 325e6, "BERT": 340e6, "DeepLight": 578e6,
}
LINK_ELEMS_PER_S = 100e9 / 8 / 4  # FP32 elements/s at 100 Gbps
CORES = 2


def run():
    rng = np.random.default_rng(0)
    n = 1 << 22
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32) * 0.01)
    scale = jnp.float32(2.0 ** 20)
    sw = jax.jit(lambda v: (jnp.round(v * scale).astype(jnp.int32).astype(jnp.float32) / scale))
    dt_sw, _ = timeit(sw, x)
    sw_elems_per_core = n / dt_sw

    for name, g in MODELS.items():
        t_link = g / LINK_ELEMS_PER_S
        # SwitchML: host transform on CORES cores + extra scale-factor round
        # (paper: overlapped but serializing at chunk granularity ~ +5% wire)
        t_sw = max(g / (sw_elems_per_core * CORES), t_link * 1.05)
        t_fp = t_link  # FPISA: raw FP32 at line rate, no host transform
        emit(f"fig11.{name}", t_sw * 1e6, f"speedup={t_sw / t_fp:.3f}")
    emit("fig11.paper_claim", 0, "up_to_1.859x_at_2cores")
