"""Paper Fig. 13 — distributed FP queries with in-switch FPISA operators vs a
Spark-like full-scan baseline. Reported: wall time ratio and prune rate for
Top-N / group-by-having-max / group-by-sum / TPC-H Q3- and Q20-like queries
on Big-Data-bench-like synthetic tables.

The query operators stream row batches through the jitted switchsim kernels
(``repro/switchsim/query.py``) — row counts here are ~10x the per-row-loop
era, and everything lands in ``BENCH_fig13.json``."""
import time

import numpy as np

from benchmarks.common import emit, scaled, write_json
from repro.db import query as q

ROWS = 2_000_000
GROUP_ROWS = 200_000


def run():
    rows = scaled(ROWS, 60_000)
    group_rows = scaled(GROUP_ROWS, 20_000)
    rng = np.random.default_rng(3)
    ad_revenue = (rng.gamma(2.0, 50.0, rows)).astype(np.float32)  # uservisits
    keys = rng.integers(0, 64, rows)
    results = {"rows": rows, "group_rows": group_rows}

    # Top-N (in-switch pruning, FP comparison)
    t0 = time.perf_counter(); pruner = q.TopNPruner(n=10)
    surv = pruner.run(ad_revenue, batch=65536)
    master = np.sort(ad_revenue[surv])[::-1][:10]
    t_sw = time.perf_counter() - t0
    t0 = time.perf_counter(); exact = q.spark_like_topn(ad_revenue, 10)
    t_base = time.perf_counter() - t0
    assert np.array_equal(master, exact)
    # the dominant cost in the real system is rows shipped to the master:
    emit("fig13.topn", t_sw * 1e6,
         f"prune_rate={pruner.stats.prune_rate:.4f};rows_to_master={pruner.stats.rows_out}")
    results["topn"] = {
        "switch_s": t_sw, "baseline_s": t_base,
        "prune_rate": pruner.stats.prune_rate,
        "rows_to_master": pruner.stats.rows_out,
        "rows_per_s": rows / t_sw,
    }

    # group-by sum over the batched scatter-accumulate dataplane kernel
    gmax = q.GroupBySum(num_slots=64, variant="full")
    gk, gv = keys[:group_rows], ad_revenue[:group_rows]
    t0 = time.perf_counter()
    agg = gmax.run(gk, gv)
    t_g = time.perf_counter() - t0
    t0 = time.perf_counter()
    exact_g = q.spark_like_groupby(gk, gv)
    t_gbase = time.perf_counter() - t0
    err = max(abs(agg[k] - v) / max(abs(v), 1e-9) for k, v in exact_g.items())
    emit("fig13.groupby_sum", t_g * 1e6,
         f"rows_to_master={gmax.stats.rows_out};max_rel_err={err:.2e}")
    results["groupby_sum"] = {
        "switch_s": t_g, "baseline_s": t_gbase, "max_rel_err": err,
        "rows_to_master": gmax.stats.rows_out,
        "rows_per_s": group_rows / t_g,
    }

    # TPC-H Q3-like: top-10 by (extendedprice) with selection predicate
    sel = ad_revenue[ad_revenue > 20.0]
    p3 = q.TopNPruner(n=10)
    s3 = p3.run(sel, batch=65536)
    assert np.array_equal(np.sort(sel[s3])[::-1][:10], q.spark_like_topn(sel, 10))
    emit("fig13.tpch_q3_like", 0, f"prune_rate={p3.stats.prune_rate:.4f}")
    results["tpch_q3_like"] = {"prune_rate": p3.stats.prune_rate}

    # TPC-H Q20-like: per-group sum then having-threshold
    g20 = q.GroupBySum(num_slots=64, variant="full")
    agg20 = g20.run(gk, gv)
    hav = {k: v for k, v in agg20.items() if v > np.mean(list(agg20.values()))}
    emit("fig13.tpch_q20_like", 0, f"groups_passing_having={len(hav)}")
    emit("fig13.paper_claim", 0, "speedup_1.9-2.7x_over_spark_from_pruning")
    results["tpch_q20_like"] = {"groups_passing_having": len(hav)}
    write_json("fig13", results)
