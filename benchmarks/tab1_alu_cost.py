"""Paper Tab. 1 analog — per-op cost of FPISA primitives vs native FP add.

The paper synthesizes switch ALUs at 15 nm (default ALU 505 um^2 / FPISA ALU
619 um^2 / hard FPU 3838 um^2). We cannot synthesize silicon; the analog is
the op-level cost of each FPISA primitive on the programmable substrate we
target: instruction/flop/byte counts from XLA cost analysis plus measured CPU
wall time per element. The headline ratio mirrors the paper's: FPISA ops cost
a small-integer multiple of a native add, versus the >5x area/power of a hard
FPU."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, scaled, timeit
from repro.core import fpisa as F

N = 1 << 20


def run():
    n = scaled(N, 1 << 14)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    y = jnp.asarray(rng.standard_normal(n), jnp.float32)

    native_add = jax.jit(lambda a, b: a + b)
    fpisa_encode = jax.jit(lambda a: F.encode(a))
    fpisa_add = jax.jit(
        lambda a, b: F.fpisa_a_add(F.encode(a), F.encode(b))[0].man
    )
    fpisa_full = jax.jit(
        lambda a, b: F.fpisa_add_full(F.encode(a), F.encode(b))[0].man
    )
    fpisa_renorm = jax.jit(lambda a: F.renormalize(F.encode(a)))

    t_add, _ = timeit(native_add, x, y)
    rows = [
        ("tab1.native_fp_add", native_add, (x, y)),
        ("tab1.fpisa_encode", fpisa_encode, (x,)),
        ("tab1.fpisa_a_add", fpisa_add, (x, y)),
        ("tab1.fpisa_full_add", fpisa_full, (x, y)),
        ("tab1.fpisa_renormalize", fpisa_renorm, (x,)),
    ]
    for name, fn, args in rows:
        dt, _ = timeit(fn, *args)
        ca = jax.jit(fn).lower(*args).compile().cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # old jax returns [dict]
            ca = ca[0] if ca else {}
        flops = ca.get("flops", 0)
        emit(name, dt * 1e6, f"x_native={dt/t_add:.2f};ops_per_elem={flops/n:.1f}")
    # paper's silicon numbers for context (um^2 at 15nm, Tab. 1)
    emit("tab1.paper_area_default_alu", 0, "um2=505.4")
    emit("tab1.paper_area_fpisa_alu", 0, "um2=618.6;ratio=1.22")
    emit("tab1.paper_area_alu_plus_fpu", 0, "um2=3837.7;ratio=7.59")
