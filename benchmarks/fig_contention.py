"""Contention figure (beyond-paper) — what sharing one switch costs each job.

Two training jobs plus one query stream (``db.query.StreamedGroupBySum``)
contend for a single emulated dataplane under QoS-aware slot admission
(quota/weight/priority, DESIGN.md §10), versus each job running alone on an
identical switch. Results in ``BENCH_contention.json``:

* per-job goodput (payload elements delivered per driver round) shared vs
  isolated, and the slowdown each job absorbs;
* Jain's fairness index over the normalized goodputs (shared/isolated) —
  1.0 means contention taxed every tenant equally;
* per-job admission counters (packets, admission_denied, preempted) showing
  HOW the arbiter resolved the contention;
* the query stream's max relative group-sum error vs the exact
  ``spark_like_groupby`` baseline (FPISA quantization only — sharing the
  switch must not corrupt results).
"""
import numpy as np

from benchmarks.common import emit, scaled, write_json

ELEMS = 64
DROP = 0.05
NUM_SLOTS = 8
PRIORITIES = (1, 0, 0)
WEIGHTS = (2, 1, 1)


def _goodput(elems: int, rounds: int) -> float:
    return elems / max(rounds, 1)


def run() -> None:
    from repro import switchsim as ss
    from repro.db import query as Q

    rng = np.random.default_rng(0)
    nchunks = scaled(256, 24)
    nrows = scaled(200_000, 10_000)

    # two training jobs: 4-worker gradient streams
    train = [(rng.standard_normal((4, nchunks * ELEMS)) * 0.01)
             .astype(np.float32) for _ in range(2)]
    # one query stream: group-by partials, one packet per row batch
    keys = rng.integers(0, 32, size=nrows)
    values = (rng.standard_normal(nrows) * 3).astype(np.float32)
    gb = Q.StreamedGroupBySum(num_groups=32, elems_per_packet=ELEMS)
    qvec = gb.vectors(keys, values, batch=scaled(4096, 1024))
    vectors = [train[0], train[1], qvec]

    cfg = ss.DataplaneConfig(
        num_workers=9, num_slots=NUM_SLOTS, elems_per_packet=ELEMS,
        num_jobs=3, job_workers=(4, 4, 1),
        job_priorities=PRIORITIES, job_weights=WEIGHTS)
    flats, rep = ss.run_multitenant(
        ss.BatchedDataplane(cfg), vectors, drop_prob=DROP, seed=1)

    # isolated baselines: the same traffic, each job alone on its own switch
    isolated_rounds = []
    for v in vectors:
        cfg1 = ss.DataplaneConfig(num_workers=v.shape[0],
                                  num_slots=NUM_SLOTS, elems_per_packet=ELEMS)
        dp = ss.BatchedDataplane(cfg1)
        (_,), r1 = ss.run_multitenant(dp, [v], drop_prob=DROP, seed=1)
        isolated_rounds.append(r1["done_round"][0])

    jobs = []
    normalized = []
    for j, v in enumerate(vectors):
        g_sh = _goodput(v.size, rep["done_round"][j])
        g_iso = _goodput(v.size, isolated_rounds[j])
        normalized.append(g_sh / g_iso)
        s = rep["job_stats"][j]
        jobs.append({
            "job": j,
            "kind": "query" if j == 2 else "train",
            "workers": v.shape[0],
            "elems": int(v.size),
            "done_round_shared": rep["done_round"][j],
            "done_round_isolated": isolated_rounds[j],
            "goodput_shared_eps": g_sh,
            "goodput_isolated_eps": g_iso,
            "normalized_goodput": normalized[-1],
            "packets": s["packets"],
            "admission_denied": s["admission_denied"],
            "preempted": s["preempted"],
        })
        emit(f"contention.job{j}_goodput", 0,
             f"shared={g_sh:.0f}eps norm={normalized[-1]:.2f}")

    # query-stream accuracy: sharing must cost quantization only
    got = gb.finalize(flats[2])
    want = Q.spark_like_groupby(keys, values)
    max_rel_err = max(abs(got[k] - want[k]) / (abs(want[k]) + 1e-9)
                      for k in want)

    fairness = {
        "jain_normalized": ss.jain_fairness(normalized),
        "jain_shared": ss.jain_fairness(
            [j["goodput_shared_eps"] for j in jobs]),
    }
    emit("contention.jain_normalized", 0,
         f"index={fairness['jain_normalized']:.3f}")
    emit("contention.query_max_rel_err", 0, f"err={max_rel_err:.2e}")

    write_json("contention", {
        "config": {
            "num_jobs": 3,
            "num_slots": NUM_SLOTS,
            "drop_prob": DROP,
            "priorities": list(PRIORITIES),
            "weights": list(WEIGHTS),
        },
        "jobs": jobs,
        "fairness": fairness,
        "query": {"max_rel_err": max_rel_err, "num_groups": 32,
                  "rows": int(nrows)},
        "completed": all(d is not None for d in rep["done_round"]),
        "rounds": rep["rounds"],
    })


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
