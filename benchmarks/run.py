"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module's docstring for
what it reproduces and the paper's claim it is checked against).
"""
import sys
import traceback
from time import perf_counter

MODULES = [
    "tab1_alu_cost",
    "fig7_gradient_ratio",
    "fig8_error_dist",
    "fig9_convergence",
    "fig10_goodput",
    "fig11_e2e_speedup",
    "fig13_queries",
    "fig_recovery",
    "fig_contention",
    "fig_serve",
    "tab3_resource_util",
    "roofline",
    "fig_autotune",
]


def main() -> None:
    print("name,us_per_call,derived")
    only = sys.argv[1:] or None
    for name in MODULES:
        if only and name not in only:
            continue
        t0 = perf_counter()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run()
            print(f"{name}.wall,{(perf_counter()-t0)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001 — report, keep going
            traceback.print_exc()
            print(f"{name}.wall,{(perf_counter()-t0)*1e6:.0f},ERROR:{type(e).__name__}")


if __name__ == "__main__":
    main()
