"""Roofline table builder: reads dry-run JSONL results and renders the
per-(arch x shape) three-term table for EXPERIMENTS.md §Roofline.

Also benchmarks the FPISA pre-collective transform backends head-to-head
(``kernel_bench``): pure-jnp block_encode vs the two-pass Pallas pipeline
(extract -> HBM round-trip -> align) vs the fused single-pass kernel, with
measured effective bandwidth (useful bytes / wall time) and the analytic HBM
plane traffic each variant incurs on TPU. The fused kernel must meet or beat
the two-pass kernel — that is the tentpole claim, measured here rather than
asserted."""
import json
import os

from benchmarks.common import emit, scaled, timed, write_json

RESULTS = [
    ("single", "results/dryrun_single.jsonl"),
    ("multi", "results/dryrun_multi.jsonl"),
]


def load(path):
    rows = {}
    if not os.path.exists(path):
        return rows
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        rows[(r["arch"], r["shape"])] = r  # later lines win (reruns)
    return rows


def fmt_row(r):
    rf = r.get("roofline", {})
    pd = r.get("per_device", {})
    dom = rf.get("bottleneck", "-")
    terms = {k: rf.get(k, 0.0) for k in ("compute_s", "memory_s", "collective_s")}
    peak = max(terms.values()) if terms else 0
    frac = terms.get("compute_s", 0) / peak if peak else 0
    return (
        f"{r['arch']:18s} {r['shape']:11s} {r['status']:7s} "
        f"cmp={terms['compute_s']:9.3f} mem={terms['memory_s']:9.3f} "
        f"col={terms['collective_s']:9.3f} dom={dom:10s} "
        f"peakGB={pd.get('peak_bytes', 0)/2**30:8.1f} "
        f"useful={r.get('useful_flops_ratio') or 0:.3f} "
        f"rl_frac={frac:.3f}"
    )


def markdown_table(rows):
    hdr = ("| arch | shape | status | compute_s | memory_s | collective_s | "
           "bottleneck | peak GB/dev | MODEL/HLO flops | roofline frac |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for (a, s), r in sorted(rows.items()):
        rf = r.get("roofline", {})
        pd = r.get("per_device", {})
        terms = [rf.get(k) for k in ("compute_s", "memory_s", "collective_s")]
        if r["status"] != "ok":
            lines.append(f"| {a} | {s} | {r['status']} | - | - | - | - | - | - | - |")
            continue
        peak = max(t for t in terms if t is not None)
        frac = (terms[0] / peak) if peak else 0
        lines.append(
            f"| {a} | {s} | ok | {terms[0]:.3f} | {terms[1]:.3f} | {terms[2]:.3f} "
            f"| {rf.get('bottleneck')} | {pd.get('peak_bytes',0)/2**30:.1f} "
            f"| {r.get('useful_flops_ratio') or 0:.3f} | {frac:.3f} |"
        )
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# FPISA transform-kernel roofline: fused vs two-pass vs jnp
# ---------------------------------------------------------------------------

# analytic plane-sized HBM transfers per variant (reads + writes of (R,B)
# planes; the (R,) bmax vector is 1/B of a plane and ignored)
PLANE_TRAFFIC = {"jnp": 2, "two_pass": 8, "fused": 2}


def kernel_bench(r=None, b=256, preshift=1):
    """Times the three encode->align implementations on an (r, b) f32 grid and
    returns {variant: {seconds, eff_gbs, planes_moved}}. Effective bandwidth
    counts only the USEFUL bytes (x in + aligned man out + bmax out) — extra
    intermediate traffic shows up as lost bandwidth, which is the point."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import fpisa, numerics as nx
    from repro.kernels import ops

    if r is None:
        r = scaled(2048, 256)
    x = jnp.asarray(
        (np.random.default_rng(0).standard_normal((r, b))
         * np.exp2(np.random.default_rng(1).integers(-8, 8, (r, b)))).astype(np.float32))
    useful_bytes = x.size * 4 * 2 + r * 4  # read x + write man + write bmax
    fmt = fpisa.FP32

    @jax.jit
    def run_jnp(x):
        planes = fpisa.encode(x, fmt)
        bmax = jnp.max(planes.exp, axis=-1)
        be = bmax[:, None]
        return nx.arshift(planes.man, (be - planes.exp) + preshift), bmax

    @jax.jit
    def run_two_pass(x):
        exp, man, bmax = ops.extract(x)
        return ops.align(exp, man, bmax, preshift=preshift), bmax

    @jax.jit
    def run_fused(x):
        man_local, bmax = ops.encode_align(x)
        # residual shift to the (here: already-global) block exponent — part
        # of the hot path, so it is timed with the kernel
        return nx.arshift(man_local, preshift), bmax

    out = {}
    baseline = None
    for name, fn in [("jnp", run_jnp), ("two_pass", run_two_pass), ("fused", run_fused)]:
        dt, res = timed(f"roofline.{name}", fn, x, warmup=2, iters=5)
        if baseline is None:
            baseline = res
        else:  # all three variants must agree bit-for-bit
            assert np.array_equal(np.asarray(res[0]), np.asarray(baseline[0])), name
            assert np.array_equal(np.asarray(res[1]), np.asarray(baseline[1])), name
        out[name] = {
            "seconds": dt,
            "eff_gbs": useful_bytes / dt / 1e9,
            "planes_moved": PLANE_TRAFFIC[name],
        }
    return out


def kernel_table(rows):
    lines = ["| variant | time (ms) | effective GB/s | HBM plane transfers |",
             "|---|---|---|---|"]
    for name, r in rows.items():
        lines.append(f"| {name} | {r['seconds']*1e3:.3f} | {r['eff_gbs']:.2f} "
                     f"| {r['planes_moved']} |")
    return "\n".join(lines)


def run():
    rows = kernel_bench()
    for name, r in rows.items():
        emit(f"roofline.kernel.{name}", r["seconds"] * 1e6,
             f"eff_gbs={r['eff_gbs']:.3f};planes={r['planes_moved']}")
    fused_ok = rows["fused"]["eff_gbs"] >= rows["two_pass"]["eff_gbs"]
    emit("roofline.kernel.fused_ge_two_pass", 0, f"ok={int(fused_ok)}")
    write_json("roofline", {"kernels": rows, "fused_ge_two_pass": bool(fused_ok)})
    for mesh_name, path in RESULTS:
        rows = load(path)
        ok = sum(1 for r in rows.values() if r["status"] == "ok")
        skipped = sum(1 for r in rows.values() if r["status"] == "skipped")
        err = sum(1 for r in rows.values() if r["status"] == "error")
        emit(f"roofline.{mesh_name}_cells", 0, f"ok={ok};skipped={skipped};error={err}")
        for (a, s), r in sorted(rows.items()):
            if r["status"] == "ok":
                rf = r["roofline"]
                emit(f"roofline.{mesh_name}.{a}.{s}", 0,
                     f"dom={rf['bottleneck']};cmp={rf['compute_s']:.3f};"
                     f"mem={rf['memory_s']:.3f};col={rf['collective_s']:.3f}")


if __name__ == "__main__":
    print("==== FPISA transform kernels (fused vs two-pass vs jnp) ====")
    print(kernel_table(kernel_bench()))
    for name, path in RESULTS:
        rows = load(path)
        if rows:
            print(f"==== {name} ====")
            print(markdown_table(rows))
