"""Roofline table builder: reads dry-run JSONL results and renders the
per-(arch x shape) three-term table for EXPERIMENTS.md §Roofline."""
import json
import os

from benchmarks.common import emit

RESULTS = [
    ("single", "results/dryrun_single.jsonl"),
    ("multi", "results/dryrun_multi.jsonl"),
]


def load(path):
    rows = {}
    if not os.path.exists(path):
        return rows
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        rows[(r["arch"], r["shape"])] = r  # later lines win (reruns)
    return rows


def fmt_row(r):
    rf = r.get("roofline", {})
    pd = r.get("per_device", {})
    dom = rf.get("bottleneck", "-")
    terms = {k: rf.get(k, 0.0) for k in ("compute_s", "memory_s", "collective_s")}
    peak = max(terms.values()) if terms else 0
    frac = terms.get("compute_s", 0) / peak if peak else 0
    return (
        f"{r['arch']:18s} {r['shape']:11s} {r['status']:7s} "
        f"cmp={terms['compute_s']:9.3f} mem={terms['memory_s']:9.3f} "
        f"col={terms['collective_s']:9.3f} dom={dom:10s} "
        f"peakGB={pd.get('peak_bytes', 0)/2**30:8.1f} "
        f"useful={r.get('useful_flops_ratio') or 0:.3f} "
        f"rl_frac={frac:.3f}"
    )


def markdown_table(rows):
    hdr = ("| arch | shape | status | compute_s | memory_s | collective_s | "
           "bottleneck | peak GB/dev | MODEL/HLO flops | roofline frac |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for (a, s), r in sorted(rows.items()):
        rf = r.get("roofline", {})
        pd = r.get("per_device", {})
        terms = [rf.get(k) for k in ("compute_s", "memory_s", "collective_s")]
        if r["status"] != "ok":
            lines.append(f"| {a} | {s} | {r['status']} | - | - | - | - | - | - | - |")
            continue
        peak = max(t for t in terms if t is not None)
        frac = (terms[0] / peak) if peak else 0
        lines.append(
            f"| {a} | {s} | ok | {terms[0]:.3f} | {terms[1]:.3f} | {terms[2]:.3f} "
            f"| {rf.get('bottleneck')} | {pd.get('peak_bytes',0)/2**30:.1f} "
            f"| {r.get('useful_flops_ratio') or 0:.3f} | {frac:.3f} |"
        )
    return "\n".join(lines)


def run():
    for mesh_name, path in RESULTS:
        rows = load(path)
        ok = sum(1 for r in rows.values() if r["status"] == "ok")
        skipped = sum(1 for r in rows.values() if r["status"] == "skipped")
        err = sum(1 for r in rows.values() if r["status"] == "error")
        emit(f"roofline.{mesh_name}_cells", 0, f"ok={ok};skipped={skipped};error={err}")
        for (a, s), r in sorted(rows.items()):
            if r["status"] == "ok":
                rf = r["roofline"]
                emit(f"roofline.{mesh_name}.{a}.{s}", 0,
                     f"dom={rf['bottleneck']};cmp={rf['compute_s']:.3f};"
                     f"mem={rf['memory_s']:.3f};col={rf['collective_s']:.3f}")


if __name__ == "__main__":
    for name, path in RESULTS:
        rows = load(path)
        if rows:
            print(f"==== {name} ====")
            print(markdown_table(rows))
