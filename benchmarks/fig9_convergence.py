"""Paper Fig. 9 — training convergence with default vs FPISA-A aggregation.
Short CPU-scale run (the test-suite gate test_convergence.py enforces the
tracking bound; here we report the curves)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, scaled
from repro.configs import get_smoke_config
from repro.core import fpisa as F
from repro.models.registry import build
from repro.optim import optimizers

WORKERS = 4


def _train(mode):
    STEPS = scaled(25, 4)
    cfg = get_smoke_config("qwen1.5-0.5b").with_(num_layers=2, d_model=64)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = optimizers.OptConfig(lr=3e-3, warmup_steps=5)
    opt = optimizers.init(params, opt_cfg)
    grad_fn = jax.jit(jax.value_and_grad(model.loss))
    motif = jax.random.randint(jax.random.PRNGKey(5), (1, 8), 0, cfg.vocab_size)
    losses = []
    for step in range(STEPS):
        gs, ls = [], []
        for w in range(WORKERS):
            toks = jax.random.randint(jax.random.PRNGKey(step * 17 + w), (2, 32), 0, cfg.vocab_size)
            toks = toks.at[:, :8].set(motif).at[:, 16:24].set(motif)
            l, g = grad_fn(params, {"tokens": toks})
            gs.append(g); ls.append(float(l))
        if mode == "exact":
            grads = jax.tree.map(lambda *x: sum(x) / WORKERS, *gs)
        else:
            def agg(*x):
                stacked = jnp.stack([v.reshape(-1) for v in x]).astype(jnp.float32)
                return (F.fpisa_sum_sequential(stacked, variant="fpisa_a") / WORKERS
                        ).reshape(x[0].shape).astype(x[0].dtype)
            grads = jax.tree.map(agg, *gs)
        params, opt, _ = optimizers.update(params, grads, opt, opt_cfg)
        losses.append(float(np.mean(ls)))
    return losses


def run():
    exact = _train("exact")
    fpa = _train("fpisa_a")
    emit("fig9.exact", 0, f"loss0={exact[0]:.4f};lossN={exact[-1]:.4f}")
    emit("fig9.fpisa_a", 0, f"loss0={fpa[0]:.4f};lossN={fpa[-1]:.4f}")
    gap = abs(exact[-1] - fpa[-1]) / exact[-1]
    emit("fig9.final_gap", 0, f"rel={gap:.4f};paper_claim=lt_0.001_accuracy")
