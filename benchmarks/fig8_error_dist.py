"""Paper Fig. 8 — FPISA-A aggregation error distribution at early/middle/final
training phases. Paper: >95% of absolute errors in [1e-10, 1e-8]; overwrite
events <0.9% and left-shift overflow <0.1% of adds."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, scaled
from repro.configs import get_smoke_config
from repro.core import fpisa as F
from repro.models.registry import build
from repro.optim import optimizers

WORKERS = 8


def run():
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = optimizers.OptConfig(lr=3e-3, warmup_steps=5)
    opt = optimizers.init(params, opt_cfg)
    grad_fn = jax.jit(jax.value_and_grad(model.loss))

    steps = scaled(30, 3)
    snap = {0: "early", steps // 2: "middle", steps - 1: "final"}
    phases = {}
    for step in range(steps):
        gs = []
        for w in range(WORKERS):
            toks = jax.random.randint(
                jax.random.PRNGKey(step * WORKERS + w), (2, 64), 0, cfg.vocab_size
            )
            _, g = grad_fn(params, {"tokens": toks})
            gs.append(np.concatenate([np.asarray(l, np.float32).ravel()
                                      for l in jax.tree.leaves(g)]))
        stacked = np.stack(gs)
        if step in snap:
            out, stats = F.fpisa_sum_sequential(
                jnp.asarray(stacked), variant="fpisa_a", return_stats=True
            )
            exact = stacked.astype(np.float64).sum(0)
            err = np.abs(np.asarray(out, np.float64) - exact)
            nz = err > 0
            phase = snap[step]
            in_band = np.mean((err[nz] >= 1e-10) & (err[nz] <= 1e-8)) if nz.any() else 0
            phases[phase] = dict(
                band=float(in_band),
                p50=float(np.quantile(err, 0.5)),
                p99=float(np.quantile(err, 0.99)),
                overwrite_frac=float(stats["overwrite"]) / stacked.size,
            )
        # cheap update with worker-0 grads to move through training phases
        _, g0 = grad_fn(params, {"tokens": jax.random.randint(
            jax.random.PRNGKey(step), (2, 64), 0, cfg.vocab_size)})
        params, opt, _ = optimizers.update(params, g0, opt, opt_cfg)

    for phase, d in phases.items():
        emit(f"fig8.{phase}", 0,
             f"err_in_[1e-10,1e-8]={d['band']:.3f};p50={d['p50']:.2e};"
             f"p99={d['p99']:.2e};overwrite_frac={d['overwrite_frac']:.5f}")
    emit("fig8.paper_claim", 0, "band>0.95;overwrite<0.009")


def _unflat(vec, like):
    out, at = [], 0
    for l in jax.tree.leaves(like):
        out.append(vec[at: at + l.size].reshape(l.shape))
        at += l.size
    return out
