"""Paper Fig. 7 — element-wise Max/Min ratio distribution across workers'
gradients. The paper finds ~83% of elements have ratio < 2^7 (the FPISA-A
headroom), which is why the overwrite path is rare. We reproduce with real
gradients from a small LM trained in-repo over 8 simulated workers."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.models.registry import build

WORKERS = 8


def run():
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    grad_fn = jax.jit(jax.grad(model.loss))

    grads = []
    for w in range(WORKERS):
        toks = jax.random.randint(jax.random.PRNGKey(100 + w), (2, 64), 0, cfg.vocab_size)
        g = grad_fn(params, {"tokens": toks})
        grads.append(np.concatenate([np.asarray(l, np.float64).ravel()
                                     for l in jax.tree.leaves(g)]))
    g = np.abs(np.stack(grads))  # (W, N)
    nz = (g > 0).all(axis=0)
    ratio = g[:, nz].max(axis=0) / g[:, nz].min(axis=0)
    for thresh, label in [(2**3, "lt_2^3"), (2**5, "lt_2^5"), (2**7, "lt_2^7"),
                          (2**9, "lt_2^9")]:
        emit(f"fig7.ratio_{label}", 0, f"frac={np.mean(ratio < thresh):.3f}")
    emit("fig7.paper_claim", 0, "frac_lt_2^7~0.83")
