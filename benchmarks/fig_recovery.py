"""Recovery figure (beyond-paper) — what a worker death costs, end to end.

Two halves, results in ``BENCH_recovery.json``:

1. Switch-side: an all-reduce through the batched dataplane with a worker
   killed mid-stream vs an uninterrupted run. Measures the reclaimed slot
   count, the completion-time overhead of the failure (detection latency +
   survivor resubmission from shadow copies) and the accepted-packet goodput
   in both runs. No slot stays parked: the faulted run COMPLETES — that is
   the property the ``reclaimed`` machinery buys (the pre-reclamation
   dataplane would spin until ``max_rounds`` and raise).

2. Training-side: the elastic controller (runtime/controller.py) in a
   subprocess with 8 host devices, one host killed mid-run. Measures
   steps-to-detect (heartbeat timeout), steps replayed (checkpoint cadence),
   wall-clock recovery overhead vs the uninterrupted run, and post-failure
   goodput (tok/s on the survivor mesh vs before the kill) — while asserting
   the loss trajectories are bit-identical (the acceptance invariant).
"""
import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import emit, scaled, write_json

W = 8
ELEMS = 256
DROP = 0.01


def _switch_half() -> dict:
    from repro import switchsim as ss

    rng = np.random.default_rng(0)
    nchunks = scaled(2048, 128)
    vecs = (rng.standard_normal((W, nchunks * ELEMS)) * 0.01).astype(np.float32)
    # window (slots * pipelines = 32) << nchunks: the kill lands mid-stream
    # with a full in-flight window to reclaim
    cfg = ss.DataplaneConfig(num_workers=W, num_slots=16,
                             elems_per_packet=ELEMS, num_pipelines=2)

    def run(fail_round):
        dp = ss.BatchedDataplane(cfg)
        ss.run_aggregation(ss.BatchedDataplane(cfg), vecs, drop_prob=DROP,
                           seed=3, fail_worker=3 if fail_round else None,
                           fail_round=fail_round)  # warm the jit variants
        t0 = time.perf_counter()
        ss.run_aggregation(dp, vecs, drop_prob=DROP, seed=3,
                           fail_worker=3 if fail_round else None,
                           fail_round=fail_round, detect_rounds=2)
        dt = time.perf_counter() - t0
        return dt, dp.stats

    clean_dt, clean_stats = run(None)
    fault_dt, fault_stats = run(1)
    out = {
        "num_workers": W,
        "drop_prob": DROP,
        "nchunks": nchunks,
        "clean_s": clean_dt,
        "faulted_s": fault_dt,
        "overhead_x": fault_dt / clean_dt,
        "reclaimed": fault_stats["reclaimed"],
        "clean_goodput_pps": clean_stats["packets"] / clean_dt,
        "faulted_goodput_pps": fault_stats["packets"] / fault_dt,
        "completed": True,  # run_aggregation raises on parked slots
        "stats": fault_stats,
    }
    emit("recovery.switch_reclaimed", 0, f"slots={out['reclaimed']}")
    emit("recovery.switch_overhead", fault_dt * 1e6,
         f"x_clean={out['overhead_x']:.2f}")
    return out


_TRAIN_CODE = r"""
import json, tempfile, sys
from repro.configs import get_smoke_config
from repro.core.agg import AggConfig
from repro.runtime.controller import ElasticController

steps, kill_at = {steps}, {kill_at}
cfg = get_smoke_config("qwen1.5-0.5b")
agg = AggConfig(strategy="fpisa", bucket_bytes=1 << 16)

def run(fault):
    return ElasticController(cfg, steps=steps, global_batch=8, seq_len=64,
                             agg=agg, ckpt_dir=tempfile.mkdtemp(),
                             ckpt_every=3, fault_plan=fault,
                             log_every=10**6).run()

base = run("")
faulted = run("kill:2@" + str(kill_at))
assert base["history"] == faulted["history"], "trajectory diverged"
print("RESULT" + json.dumps({{"base": base["timeline"],
                              "faulted": faulted["timeline"],
                              "recovery": faulted["recoveries"][0]}}))
"""


def _train_half() -> dict:
    steps = scaled(24, 10)
    kill_at = steps // 2
    code = _TRAIN_CODE.format(steps=steps, kill_at=kill_at)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=1800, env=env)
    if res.returncode != 0:
        raise RuntimeError(f"controller subprocess failed:\n{res.stderr[-3000:]}")
    payload = json.loads(next(l for l in res.stdout.splitlines()
                              if l.startswith("RESULT"))[len("RESULT"):])
    rec = payload["recovery"]
    faulted = payload["faulted"]
    wall = {"base": sum(e["dt"] for e in payload["base"]),
            "faulted": sum(e["dt"] for e in faulted)}
    # post-failure entries are the tail computed on the survivor mesh
    post = [e for e in faulted if e["mesh"] < W][1:]  # [0] is the re-jit step
    pre = [e for e in faulted if e["mesh"] == W][1:kill_at]
    out = {
        "steps": steps,
        "kill_at": kill_at,
        "steps_to_detect": rec["steps_to_detect"],
        "steps_replayed": rec["steps_replayed"],
        "steps_to_recover": rec["steps_to_detect"] + rec["steps_replayed"],
        "reclaimed": rec["reclaimed"],
        "survivor_mesh": rec["mesh_hosts"],
        "wall_clean_s": wall["base"],
        "wall_faulted_s": wall["faulted"],
        "recovery_overhead_x": wall["faulted"] / wall["base"],
        "pre_failure_tok_s": (8 * 64 * len(pre) / sum(e["dt"] for e in pre)
                              if pre else 0.0),
        "post_failure_tok_s": (8 * 64 * len(post) / sum(e["dt"] for e in post)
                               if post else 0.0),
        "bit_identical": True,  # asserted inside the subprocess
    }
    emit("recovery.steps_to_recover", 0,
         f"detect={out['steps_to_detect']};replay={out['steps_replayed']}")
    emit("recovery.post_failure_tok_s", 0,
         f"tok_s={out['post_failure_tok_s']:.0f};"
         f"pre={out['pre_failure_tok_s']:.0f}")
    return out


def run():
    write_json("recovery", {
        "switch": _switch_half(),
        "training": _train_half(),
    })
