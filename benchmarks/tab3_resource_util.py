"""Paper Tab. 3 analog — resource utilization of the FPISA aggregation
program. The Tofino table reports SRAM/TCAM/ALU/VLIW-slot usage; the TPU
analog is the HLO op census of the compiled FPISA all-reduce step (which op
categories the program spends its instruction budget on)."""
import collections
import re

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import fpisa as F
from repro.core import numerics as nx


def run():
    n = 1 << 16

    def fpisa_agg(x, w):
        # single-host emulation of the full pipeline: encode+align+sum+renorm
        p = F.encode(x)
        bmax = F.block_max_exponent(p.exp, 256)
        man = F.block_encode(x, bmax, 256, nx.required_preshift(w))
        s = man * w  # stand-in for the integer reduction
        return F.block_decode(s, bmax, 256, nx.required_preshift(w))

    x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
    txt = jax.jit(fpisa_agg, static_argnums=1).lower(x, 8).compile().as_text()
    census = collections.Counter()
    for m in re.finditer(r"=\s*\S+\s+([a-z][\w\-]*)\(", txt):
        census[m.group(1)] += 1
    total = sum(census.values())
    top = census.most_common(8)
    emit("tab3.hlo_ops_total", 0, f"n={total}")
    for op, c in top:
        emit(f"tab3.op_{op}", 0, f"count={c};frac={c/total:.3f}")
    emit("tab3.paper_claim", 0, "tofino:9of12_stages;VLIW_96.9pct_max_MAU")
