"""Autotuner proof benchmark — ``--bucket-bytes auto`` vs the default plan.

End-to-end run of the DESIGN.md §13 pipeline on the fig11 gradient tree:

1. **Profile**: replay the fpisa split-phase pipeline at probe sizes under
   synced tracer spans (``repro.autotune.profile.profile_phases``) and export
   the trace JSONL — the same artifact ``--trace-out`` produces.
2. **Fit**: per-phase affine cost model from that trace
   (``repro.autotune.costmodel.fit_from_jsonl``).
3. **Search**: sweep candidate ``bucket_bytes`` plans over the eval tree's
   leaves (``repro.autotune.search.choose_bucket_bytes``) — the exact
   resolution path ``AggConfig.from_args`` runs for ``--bucket-bytes auto``.
4. **Prove**: measure the tuned plan against the default — the blind
   fallback plan ``--bucket-bytes auto`` resolves to when NO trace exists
   (``search.DEFAULT_AUTO_BUCKET_BYTES``) — on the fig11 tree. Acceptance:
   tuned is bit-identical and no slower at smoke size, faster at full size.
   (Per-leaf ``bucket_bytes=0`` is also swept as a candidate, so the tuner
   can and does fall back to it when the model says bucketing loses.)

Results land in ``BENCH_autotune.json`` (schema checked by
tests/test_benchmarks.py).
"""
import os
import tempfile

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, scaled, timed, write_json
from benchmarks.fig11_e2e_speedup import _gradient_tree
from repro import compat, trace
from repro.autotune import (
    DEFAULT_AUTO_BUCKET_BYTES, choose_bucket_bytes, fit_from_jsonl,
    probe_sizes, profile_phases,
)
from repro.core.agg import AggConfig, Aggregator

# the untuned baseline: what `--bucket-bytes auto` resolves to with no trace
DEFAULT_BUCKET_BYTES = DEFAULT_AUTO_BUCKET_BYTES


def _trace_path() -> str:
    base = os.environ.get("BENCH_DIR") or tempfile.gettempdir()
    return os.path.join(base, "TRACE_autotune.jsonl")


def run():
    cfg = AggConfig(strategy="fpisa", backend="jnp")

    # 1. profile under a live global tracer, export the trace JSONL
    trace.enable()
    sizes = probe_sizes(block=cfg.block,
                        max_elems=scaled(1 << 20, 1 << 14))
    spans = profile_phases(cfg, sizes=sizes, iters=scaled(3, 2), warmup=1)
    path = _trace_path()
    trace.write_jsonl(trace.get(), path)
    trace.disable()
    emit("autotune.profile", 0,
         f"probes={len(sizes)};spans={len(spans)};trace={path}")

    # 2-3. fit + search over the eval tree's leaves
    rng = np.random.default_rng(0)
    n_layers = scaled(64, 6)
    tree = _gradient_tree(rng, n_layers)
    leaves = list(tree.values())
    model = fit_from_jsonl(path)
    tuned, scores = choose_bucket_bytes(model, leaves, block=cfg.block)
    emit("autotune.search", scores[tuned] * 1e6,
         f"tuned_bucket_bytes={tuned};candidates={len(scores)}")

    # 4. measure tuned vs default on the fig11 harness
    mesh = compat.make_mesh((jax.device_count(),), ("data",))

    def make(bucket_bytes: int):
        agg = Aggregator(AggConfig(strategy="fpisa", backend="jnp",
                                   bucket_bytes=bucket_bytes), ("data",))
        return jax.jit(compat.shard_map(
            agg.allreduce_tree, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), tree),),
            out_specs=jax.tree.map(lambda _: P(), tree), check_vma=False))

    default_fn = make(DEFAULT_BUCKET_BYTES)
    # identical plan -> identical program: reuse the executable so the
    # comparison measures the plan, not compile-to-compile variance
    tuned_fn = default_fn if tuned == DEFAULT_BUCKET_BYTES else make(tuned)
    a, b = default_fn(tree), tuned_fn(tree)
    bit_identical = all(
        bool(jnp.all(a[k].view(jnp.int32) == b[k].view(jnp.int32)))
        for k in tree)

    iters = scaled(10, 3)
    dt_default, _ = timed("fig_autotune.default_step", default_fn, tree,
                          warmup=2, iters=iters,
                          bucket_bytes=DEFAULT_BUCKET_BYTES)
    dt_tuned, _ = timed("fig_autotune.tuned_step", tuned_fn, tree,
                        warmup=2, iters=iters, bucket_bytes=tuned)
    speedup = dt_default / dt_tuned
    no_worse = bool(dt_tuned <= dt_default * 1.05)  # 5% measurement slack
    emit("fig_autotune.tuned_agg_step", dt_tuned * 1e6,
         f"default_us={dt_default*1e6:.0f};speedup={speedup:.2f}x;"
         f"bit_identical={int(bit_identical)};no_worse={int(no_worse)}")

    write_json("autotune", {
        "workload": {
            "n_layers": n_layers,
            "n_leaves": len(leaves),
            "n_elems": int(sum(v.size for v in leaves)),
        },
        "profile": {
            "probe_sizes": list(sizes),
            "n_spans": len(spans),
            "trace_path": path,
        },
        "model": model.to_dict(),
        "search": {
            "tuned_bucket_bytes": int(tuned),
            "default_bucket_bytes": DEFAULT_BUCKET_BYTES,
            "predicted_us": {str(k): v * 1e6 for k, v in scores.items()},
        },
        "comparison": {
            "default_us": dt_default * 1e6,
            "tuned_us": dt_tuned * 1e6,
            "speedup": speedup,
            "no_worse": no_worse,
            "bit_identical": bit_identical,
        },
    })


if __name__ == "__main__":
    run()
