"""Paper Fig. 10 — goodput. Two halves:

1. Host-side transform throughput: SwitchML's quantize path (scale-factor
   apply + round + int convert + dequantize) vs FPISA's encode path (bit
   extract + align; no scale round trip). The paper's claim: FPISA needs
   25-75% fewer CPU cores to sustain line rate.
2. Switch dataplane packet rate: the batched jit-compiled multi-pipeline
   emulator (``repro/switchsim``) vs the legacy per-packet emulator
   (``core/switch.FpisaSwitch``), both running the full lossy all-reduce
   protocol at ``num_workers=8, drop_prob=0.01``. The batched dataplane must
   sustain >= 100x the per-packet emulator's packets/sec, with bit-identical
   ``run_aggregation`` output for identical seeds. Results (both rates + the
   parity bit) land in ``BENCH_fig10.json``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, scaled, timed, timeit, write_json
from repro.core import fpisa as F
from repro.core import numerics as nx

N = 1 << 22
LINE_RATE_ELEMS = 100e9 / 8 / 4  # FP32 elements/s at 100 Gbps

# dataplane comparison setup (acceptance-pinned: W=8, drop 1%)
DP_WORKERS = 8
DP_DROP = 0.01
DP_ELEMS = 256


def _packets(stats) -> int:
    return stats["packets"] + stats["duplicates"] + stats["stale"]


def bench_dataplane():
    """Packets/sec: batched multi-pipeline dataplane vs per-packet emulator."""
    from repro import switchsim as ss
    from repro.core import switch as sw

    rng = np.random.default_rng(0)

    # --- parity: identical workload + seed through both paths, bit-compare.
    # P=1 so the chunk->slot mapping matches the single-pipeline legacy switch.
    par_cfg = dict(num_workers=DP_WORKERS, num_slots=16, elems_per_packet=DP_ELEMS)
    vec_par = (rng.standard_normal((DP_WORKERS, 48 * DP_ELEMS)) * 0.01).astype(np.float32)
    dp = ss.BatchedDataplane(ss.DataplaneConfig(**par_cfg, num_pipelines=1))
    legacy = sw.FpisaSwitch(sw.SwitchConfig(**par_cfg))
    a = ss.run_aggregation(dp, vec_par, drop_prob=DP_DROP, seed=7)
    b = ss.run_aggregation(legacy, vec_par, drop_prob=DP_DROP, seed=7)
    bit_identical = bool(np.array_equal(a.view(np.int32), b.view(np.int32)))

    # --- legacy per-packet rate (warm: the parity run above compiled it).
    # The shim's measured rate matches the pre-refactor pure-python+jnp
    # emulator almost exactly (~550 pps on this host, measured against the
    # seed implementation), so this baseline is the genuine per-packet cost.
    legacy = sw.FpisaSwitch(sw.SwitchConfig(**par_cfg))
    dt_legacy, _ = timed("fig10.dataplane_legacy", ss.run_aggregation, legacy,
                         vec_par, DP_DROP, 2, warmup=0, iters=1)
    legacy_pps = _packets(legacy.stats) / dt_legacy

    # --- batched multi-pipeline rate at ~100x the legacy packet volume
    cfg = ss.DataplaneConfig(num_workers=DP_WORKERS, num_slots=128,
                             elems_per_packet=DP_ELEMS, num_pipelines=4)
    nchunks = scaled(8192, 512)  # 8192 * 256 = 2M gradient elements per worker
    vec = (rng.standard_normal((DP_WORKERS, nchunks * DP_ELEMS)) * 0.01).astype(np.float32)
    # warm: full identical run primes every (batch size, rounds) jit variant
    ss.run_aggregation(ss.BatchedDataplane(cfg), vec, drop_prob=DP_DROP, seed=2)
    dp = ss.BatchedDataplane(cfg)
    dt_batched, _ = timed("fig10.dataplane_batched", ss.run_aggregation, dp,
                          vec, DP_DROP, 2, warmup=0, iters=1)
    batched_pps = _packets(dp.stats) / dt_batched

    speedup = batched_pps / legacy_pps
    emit("fig10.dataplane_legacy_pps", 0, f"pps={legacy_pps:.0f}")
    emit("fig10.dataplane_batched_pps", 0,
         f"pps={batched_pps:.0f};speedup={speedup:.0f}x;bit_identical={int(bit_identical)}")
    return {
        "num_workers": DP_WORKERS,
        "drop_prob": DP_DROP,
        "legacy_pps": legacy_pps,
        "batched_pps": batched_pps,
        "speedup": speedup,
        "speedup_target": 100.0,
        "speedup_ok": bool(speedup >= 100.0),
        "bit_identical": bit_identical,
        "batched": {"num_pipelines": cfg.num_pipelines, "num_slots": cfg.num_slots,
                    "nchunks": nchunks, "stats": dp.stats},
        "legacy_stats": legacy.stats,
    }


def run():
    n = scaled(N, 1 << 16)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32) * 0.01)
    scale = jnp.float32(2.0 ** 20)

    # SwitchML host path: quantize (x*scale -> int32) + dequantize
    def switchml_host(v):
        q = jnp.round(v * scale).astype(jnp.int32)
        return (q.astype(jnp.float32) / scale)

    # FPISA host path: none in steady state (values sent as-is); the encode
    # lives in the switch. We charge the worst case: a local encode+decode.
    def fpisa_host(v):
        p = F.encode(v)
        return F.renormalize(p)

    def fpisa_zero_copy(v):
        return v  # the actual FPISA host path: raw FP32 on the wire

    host = {}
    for name, fn in [
        ("fig10.switchml_host_transform", jax.jit(switchml_host)),
        ("fig10.fpisa_host_worstcase", jax.jit(fpisa_host)),
    ]:
        dt, _ = timeit(fn, x)
        elems_per_s = n / dt
        cores = max(LINE_RATE_ELEMS / elems_per_s, 0.0)
        emit(name, dt * 1e6, f"Melem_s={elems_per_s/1e6:.0f};cores_for_100Gbps={cores:.2f}")
        host[name.split(".", 1)[1]] = {
            "us_per_call": dt * 1e6, "melem_per_s": elems_per_s / 1e6,
            "cores_for_100gbps": cores}
    # the actual FPISA host path sends native FP32 buffers: ZERO transform
    # cores (the encode runs in the aggregator — switch ALUs in the paper,
    # the TPU VPU kernels here); this is the 25-75% fewer-cores claim.
    emit("fig10.fpisa_host_zero_copy", 0.0, "Melem_s=inf;cores_for_100Gbps=0.00")
    emit("fig10.paper_claim", 0, "fpisa_cores=1_vs_switchml=4;25-75pct_fewer")
    host["fpisa_host_zero_copy"] = {"us_per_call": 0.0, "cores_for_100gbps": 0.0}

    write_json("fig10", {"host_transform": host, "dataplane": bench_dataplane()})
