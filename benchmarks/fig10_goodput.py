"""Paper Fig. 10 — host-side transform throughput: SwitchML's quantize path
(scale-factor apply + round + int convert + dequantize) vs FPISA's encode path
(bit extract + align; no scale round trip). The paper's claim: FPISA needs
25-75% fewer CPU cores to sustain line rate. We measure per-element transform
cost on this host and derive cores needed for 100 Gbps of FP32 gradients."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import fpisa as F
from repro.core import numerics as nx

N = 1 << 22
LINE_RATE_ELEMS = 100e9 / 8 / 4  # FP32 elements/s at 100 Gbps


def run():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(N).astype(np.float32) * 0.01)
    scale = jnp.float32(2.0 ** 20)

    # SwitchML host path: quantize (x*scale -> int32) + dequantize
    def switchml_host(v):
        q = jnp.round(v * scale).astype(jnp.int32)
        return (q.astype(jnp.float32) / scale)

    # FPISA host path: none in steady state (values sent as-is); the encode
    # lives in the switch. We charge the worst case: a local encode+decode.
    def fpisa_host(v):
        p = F.encode(v)
        return F.renormalize(p)

    def fpisa_zero_copy(v):
        return v  # the actual FPISA host path: raw FP32 on the wire

    for name, fn in [
        ("fig10.switchml_host_transform", jax.jit(switchml_host)),
        ("fig10.fpisa_host_worstcase", jax.jit(fpisa_host)),
    ]:
        dt, _ = timeit(fn, x)
        elems_per_s = N / dt
        cores = max(LINE_RATE_ELEMS / elems_per_s, 0.0)
        emit(name, dt * 1e6, f"Melem_s={elems_per_s/1e6:.0f};cores_for_100Gbps={cores:.2f}")
    # the actual FPISA host path sends native FP32 buffers: ZERO transform
    # cores (the encode runs in the aggregator — switch ALUs in the paper,
    # the TPU VPU kernels here); this is the 25-75% fewer-cores claim.
    emit("fig10.fpisa_host_zero_copy", 0.0, "Melem_s=inf;cores_for_100Gbps=0.00")
    emit("fig10.paper_claim", 0, "fpisa_cores=1_vs_switchml=4;25-75pct_fewer")
