"""Serving figure (beyond-paper) — continuous batching + paged KV vs static.

FPISA's headline serving claim is CPU-side efficiency (25-75% fewer cores,
up to 85.9% better throughput); this benchmark measures the serving-path
analogue in this repo: the continuous-batching engine
(``repro.serve.scheduler``) against the static-batch engine on the SAME
mixed-length Poisson workload at the SAME slot count. Results in
``BENCH_serve.json``:

* goodput (generated tok/s, wall clock after a warmup pass compiles both
  engines) static vs continuous, and the ratio against the >= 1.3x
  acceptance target;
* TTFT / TPOT p50/p99 in scheduler-step units under Poisson load
  (one step == one decode iteration for both engines, so the latency
  distributions are directly comparable);
* peak KV pages in use vs the dense ``num_slots * max_len`` footprint the
  static engine pins;
* the bit-identity parity bit: every continuous-engine request's greedy
  tokens equal the per-request static oracle's, token for token.

Timing claims (`goodput_ok`) are asserted at full size only; BENCH_SMOKE=1
shrinks the trace but still checks identity and the paged < dense bit.
"""
import time
import warnings

import numpy as np

from benchmarks.common import emit, scaled, write_json

GOODPUT_TARGET = 1.3


def _static_latencies(batches):
    """Static-engine TTFT/TPOT in scheduler-step units: batch k's requests
    all wait for batches 0..k-1 (each runs max(effs) lockstep steps plus one
    prefill step), get their first token at their own batch's prefill, then
    one token per step. ``batches``: lists of (t_arrival, eff_budget)."""
    ttfts, tpots = [], []
    t = 0.0
    for batch in batches:
        t += 1.0  # this batch's prefill step emits every first token
        for t_arr, eff in batch:
            ttfts.append(t - t_arr)
            if eff > 1:
                tpots.append(1.0)  # lockstep: one token per decode step
        t += max(e for _, e in batch) - 1
    return ttfts, tpots


def run() -> None:
    import jax

    from repro.configs import get_smoke_config
    from repro.models.registry import build
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.loadgen import PoissonLoadGen, percentile
    from repro.serve.scheduler import ContinuousEngine

    n_requests = scaled(48, 10)
    num_slots = scaled(8, 3)
    max_len = scaled(128, 32)
    page_size = 8
    lg = PoissonLoadGen(
        rate=scaled(1.5, 0.8),
        prompt_lens=scaled((8, 16, 32, 64), (4, 8, 12)),
        max_new=scaled((4, 8, 16, 32, 64), (2, 5, 9)),
        vocab_size=256, seed=17)

    cfg = get_smoke_config("qwen1.5-0.5b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # ONE explicitly seeded Generator threaded through every stochastic
    # draw of the benchmark (loadgen contract) — BENCH_serve.json must be
    # reproducible across processes
    bench_rng = np.random.default_rng(lg.seed)
    trace = lg.trace(n_requests, rng=bench_rng)
    reqs = [r for _, r in trace]

    def fresh(rs):
        return [Request(r.rid, np.array(r.prompt), r.max_new_tokens)
                for r in rs]

    # --- continuous engine: warmup pass compiles, second pass is timed ----
    def run_continuous():
        eng = ContinuousEngine(model, params, num_slots=num_slots,
                               max_len=max_len, page_size=page_size)
        out = eng.run_trace([(t, r) for (t, _), r in
                             zip(trace, fresh(reqs))])
        return eng, out

    run_continuous()  # warmup (jit caches persist on the model functions)
    eng, cont_results = run_continuous()
    cont_tokens = sum(len(r.tokens) for r in cont_results)
    cont_s = eng.last_wall_s
    stats = eng.latency_stats()
    cont_ttft = [s.ttft for s in stats]
    cont_tpot = [s.tpot for s in stats if s.n_generated > 1]

    # --- static engine on the same workload, same slot count --------------
    def run_static():
        s_eng = ServeEngine(model, params, batch_size=num_slots,
                            max_len=max_len)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            t0 = time.perf_counter()
            out = s_eng.run(fresh(reqs))
            dt = time.perf_counter() - t0
        return s_eng, out, dt

    run_static()  # warmup
    s_eng, stat_results, stat_s = run_static()
    stat_tokens = sum(len(r.tokens) for r in stat_results)

    # static latencies in the same step units
    arrivals = [(t, r) for (t, _), r in zip(trace, reqs)]
    batches = []
    for i in range(0, len(arrivals), num_slots):
        chunk = arrivals[i:i + num_slots]
        plen = max(len(r.prompt) for _, r in chunk)
        batches.append([(t, min(r.max_new_tokens, max_len - plen + 1))
                        for t, r in chunk])
    s_ttft, s_tpot = _static_latencies(batches)

    # --- parity: continuous == per-request static oracle ------------------
    oracle = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for r in fresh(reqs):
            o_eng = ServeEngine(model, params, batch_size=1, max_len=max_len)
            oracle[r.rid] = o_eng.run([r])[0].tokens
    bit_identical = all(
        np.array_equal(res.tokens, oracle[res.rid]) for res in cont_results)

    cont_goodput = cont_tokens / max(cont_s, 1e-9)
    stat_goodput = stat_tokens / max(stat_s, 1e-9)
    ratio = cont_goodput / max(stat_goodput, 1e-9)
    pages_peak = eng.cache.peak_pages_in_use
    paged_tokens_peak = pages_peak * page_size
    dense_tokens = num_slots * max_len

    emit("serve.static_goodput_tok_s", stat_s * 1e6, f"{stat_goodput:.1f}")
    emit("serve.continuous_goodput_tok_s", cont_s * 1e6,
         f"{cont_goodput:.1f}")
    emit("serve.goodput_ratio", 0, f"{ratio:.2f}x (target {GOODPUT_TARGET}x)")
    emit("serve.kv_pages_peak", 0,
         f"{pages_peak} pages = {paged_tokens_peak} tok vs dense "
         f"{dense_tokens} tok")
    emit("serve.bit_identical", 0, str(bit_identical))

    write_json("serve", {
        "workload": {
            "n_requests": n_requests, "num_slots": num_slots,
            "max_len": max_len, "page_size": page_size, "rate": lg.rate,
            "prompt_lens": list(lg.prompt_lens), "max_new": list(lg.max_new),
            "seed": lg.seed,
        },
        "static": {
            "goodput_tok_s": stat_goodput, "wall_s": stat_s,
            "tokens": stat_tokens,
            "decode_steps": s_eng.telemetry["decode_steps"],
            "slot_steps": s_eng.telemetry["slot_steps"],
            "truncated_by_packing": s_eng.telemetry["truncated_by_packing"],
            "ttft_p50": percentile(s_ttft, 50),
            "ttft_p99": percentile(s_ttft, 99),
            "tpot_p50": percentile(s_tpot, 50),
            "tpot_p99": percentile(s_tpot, 99),
        },
        "continuous": {
            "goodput_tok_s": cont_goodput, "wall_s": cont_s,
            "tokens": cont_tokens,
            "decode_steps": eng.telemetry["decode_steps"],
            "slot_steps": eng.telemetry["slot_steps"],
            "prefills": eng.telemetry["prefills"],
            "queue_peak": eng.telemetry["queue_peak"],
            "ttft_p50": percentile(cont_ttft, 50),
            "ttft_p99": percentile(cont_ttft, 99),
            "tpot_p50": percentile(cont_tpot, 50),
            "tpot_p99": percentile(cont_tpot, 99),
            "kv_pages_peak": pages_peak,
            "kv_tokens_peak": paged_tokens_peak,
        },
        "comparison": {
            "goodput_ratio": ratio,
            "goodput_target": GOODPUT_TARGET,
            "goodput_ok": bool(ratio >= GOODPUT_TARGET),
            "kv_pages_peak_tokens": paged_tokens_peak,
            "dense_cache_tokens": dense_tokens,
            "paged_lt_dense": bool(paged_tokens_peak < dense_tokens),
            "bit_identical": bool(bit_identical),
        },
    })


if __name__ == "__main__":
    run()
