"""Shared AST helpers for repro-lint rules (stdlib only)."""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Tuple

__all__ = [
    "ImportMap",
    "call_name",
    "dotted",
    "literal_str_tuple",
    "top_level_defs",
    "walk_scopes",
]


def dotted(node: ast.AST) -> Optional[str]:
    """'jnp.exp2' for Attribute/Name chains; None for anything dynamic."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted(node.func)


class ImportMap:
    """Resolves local aliases back to fully-qualified import paths.

    ``import jax.numpy as jnp``       -> alias "jnp"  => "jax.numpy"
    ``from jax import lax``           -> alias "lax"  => "jax.lax"
    ``from repro.core import allreduce as AR`` -> "AR" => "repro.core.allreduce"
    """

    def __init__(self, tree: ast.AST):
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
                    if a.asname:
                        self.aliases[a.asname] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}")

    def resolve(self, name: Optional[str]) -> Optional[str]:
        """Qualify the leading segment of a dotted name via the alias map."""
        if not name:
            return None
        head, _, rest = name.partition(".")
        base = self.aliases.get(head, head)
        return f"{base}.{rest}" if rest else base

    def qualified(self, node: ast.AST) -> Optional[str]:
        return self.resolve(dotted(node))


def top_level_defs(tree: ast.Module) -> Dict[str, ast.AST]:
    """name -> def node for module-level functions/classes/assignments."""
    out: Dict[str, ast.AST] = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            out[node.name] = node
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out[tgt.id] = node
    return out


def literal_str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """('a', 'b') / ['a', 'b'] literal -> tuple of strings, else None."""
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
            else:
                return None
        return tuple(out)
    return None


def walk_scopes(tree: ast.Module) -> Iterator[ast.AST]:
    """Yield the module plus every (possibly nested) function definition —
    the linear-statement scopes the donation-safety rule analyses."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
