"""repro-lint: AST-based invariant linter for the FPISA reproduction.

Statically enforces the construction rules the repo's correctness
arguments rest on — exact pow2 scaling, bit-identical worker-axis
reduction order, jax-free host callbacks, three-way dataplane mirror
parity, jit buffer-donation safety, facade-only aggregation, and threaded
RNG state. See DESIGN.md §12 for the invariant catalog and
tools/repro_lint/README.md for usage and suppressions.

    python -m tools.repro_lint src tests benchmarks examples
    python -m tools.repro_lint --list-rules
    # per-line opt-out, with a reason:
    #   ... # repro-lint: disable=facade-only  exercising the shim itself

Stdlib-only by design: runs before (and without) the jax environment.
"""
from tools.repro_lint.engine import (  # noqa: F401
    Finding,
    LintResult,
    ModuleInfo,
    Project,
    RuleSpec,
    available_rules,
    format_findings,
    get_rule,
    main,
    register_rule,
    run_lint,
    unregister_rule,
)
from tools.repro_lint import mirror, rules  # noqa: F401  (self-registration)

__all__ = [
    "Finding",
    "LintResult",
    "ModuleInfo",
    "Project",
    "RuleSpec",
    "available_rules",
    "format_findings",
    "get_rule",
    "main",
    "register_rule",
    "run_lint",
    "unregister_rule",
]
