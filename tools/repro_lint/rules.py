"""Per-file invariant rules (project-level mirror parity lives in
``tools/repro_lint/mirror.py``; the catalog with each rule's historical bug
and approximation/false-negative space is DESIGN.md §12).

Every rule is a conservative AST approximation of an invariant the repo
argues in prose — the point is to catch the *recurrence* of bug classes
already paid for once, not to prove the invariant."""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

from tools.repro_lint.astutil import ImportMap, dotted
from tools.repro_lint.engine import Finding, ModuleInfo, Project, register_rule

# ---------------------------------------------------------------------------
# EXACT-SCALE — no inexact pow2 on decode/scale paths (PR 3's tiny-normal
# flush-to-zero: a single jnp.exp2(k) factor overflows f32 and is off by
# ulps for |k| >~ 64; scale paths must use bit-assembled exact pow2).
# ---------------------------------------------------------------------------

_INEXACT_POW2 = {
    "jax.numpy.exp2", "numpy.exp2", "math.exp2",
    "jax.numpy.float_power", "numpy.float_power",
}
_POW_FNS = {"jax.numpy.power", "numpy.power", "math.pow"}


def _is_two(node: ast.AST) -> bool:
    return (isinstance(node, ast.Constant)
            and isinstance(node.value, (int, float))
            and node.value == 2)


@register_rule(
    "exact-scale",
    scope=("src/repro/core/*", "src/repro/kernels/*"),
    description="no jnp.exp2 / float 2**e on core/kernels scale paths — "
                "use the bit-assembled exact pow2 helpers")
def exact_scale(mod: ModuleInfo, project: Project) -> Iterator[Finding]:
    imports = ImportMap(mod.tree)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            q = imports.qualified(node.func)
            bad = q in _INEXACT_POW2 or (
                q in _POW_FNS and node.args and _is_two(node.args[0]))
            if bad:
                yield Finding(
                    "exact-scale", mod.rel, node.lineno, node.col_offset,
                    f"{q.split('.')[-1]}() is not an exact power-of-two "
                    f"scale (inexact past |e| ~ 64, overflows f32 past "
                    f"2**127); use the bit-assembled helper "
                    f"(core/allreduce._pow2 / numerics bitcast)")
        elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.Pow) \
                and _is_two(node.left):
            yield Finding(
                "exact-scale", mod.rel, node.lineno, node.col_offset,
                "float 2 ** e on a scale path; use the bit-assembled "
                "exact pow2 helper (core/allreduce._pow2)")


# ---------------------------------------------------------------------------
# BIT-IDENTITY — no value-order-dependent reduce over the stacked
# logical-worker axis, and no raw flat collectives outside the facade
# (PR 4's bug: a jnp.sum over the (W,) per-worker loss vector was
# pattern-matched into a mesh-shaped cross-device all-reduce, so the scalar
# stopped being bit-reproducible across re-meshes; the fix is a fixed-order
# lax.scan — and every gradient-sized reduce goes through the Aggregator).
# ---------------------------------------------------------------------------

# implementation sites where raw collectives ARE the point
_BITID_IMPL = {
    "src/repro/core/allreduce.py",
    "src/repro/core/agg.py",
    "src/repro/core/bucketer.py",
    "src/repro/compat.py",
}
_WORKER_NAME = re.compile(r"worker|stacked|losses", re.IGNORECASE)
_ORDER_SENSITIVE = {"jax.numpy.sum", "jax.numpy.mean",
                    "jax.lax.psum", "jax.lax.pmean"}
_RAW_COLLECTIVES = {"jax.lax.psum", "jax.lax.psum_scatter"}


@register_rule(
    "bit-identity",
    scope=("src/repro/*",),
    description="no jnp.sum/mean/psum over the stacked logical-worker axis; "
                "flat reduces go through the Aggregator facade")
def bit_identity(mod: ModuleInfo, project: Project) -> Iterator[Finding]:
    if mod.rel in _BITID_IMPL:
        return
    imports = ImportMap(mod.tree)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        q = imports.qualified(node.func)
        # raw collectives first: psum is in BOTH sets, and outside the
        # implementation it is a violation regardless of the arg's name
        if q in _RAW_COLLECTIVES:
            yield Finding(
                "bit-identity", mod.rel, node.lineno, node.col_offset,
                f"raw {q.split('.')[-1]}() outside the aggregation "
                f"implementation; flat reduces must go through "
                f"Aggregator.allreduce[_tree] so strategy/wire semantics "
                f"stay in one place")
        elif q in _ORDER_SENSITIVE:
            for arg in node.args:
                name = dotted(arg)
                if name and _WORKER_NAME.search(name):
                    yield Finding(
                        "bit-identity", mod.rel, node.lineno,
                        node.col_offset,
                        f"{q.split('.')[-1]}({name}) reduces over a "
                        f"logical-worker-stacked value; on a mesh this "
                        f"becomes a cross-device reduce whose grouping "
                        f"follows the mesh size and breaks bit-identical "
                        f"recovery — use a fixed-order lax.scan or the "
                        f"Aggregator facade")
                    break


# ---------------------------------------------------------------------------
# NO-JAX-IN-CALLBACK — functions handed to jax.pure_callback/io_callback,
# transitively (same module), must never re-enter jax (PR 2's deadlock: all
# CPU PJRT executor threads park inside concurrent host callbacks, so a
# nested jitted dispatch can never be scheduled).
# ---------------------------------------------------------------------------

_CALLBACK_FNS = {
    "jax.pure_callback", "jax.experimental.pure_callback",
    "jax.experimental.io_callback", "jax.debug.callback",
}


def _function_defs(tree: ast.Module) -> Dict[str, ast.AST]:
    """Every (possibly nested) def/lambda-binding in the module, by name."""
    defs: Dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, node)
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Lambda):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    defs.setdefault(tgt.id, node.value)
    return defs


def _callback_target(arg: ast.AST, imports: ImportMap,
                     defs: Dict[str, ast.AST]) -> Optional[ast.AST]:
    if isinstance(arg, ast.Lambda):
        return arg
    if isinstance(arg, ast.Call):  # functools.partial(f, ...)
        q = imports.qualified(arg.func)
        if q in ("functools.partial", "partial") and arg.args:
            return _callback_target(arg.args[0], imports, defs)
        return None
    if isinstance(arg, ast.Name):
        return defs.get(arg.id)
    return None


def _jax_refs(fn: ast.AST, imports: ImportMap, defs: Dict[str, ast.AST],
              seen: Set[int]) -> Iterator[ast.AST]:
    """Yield nodes inside ``fn`` (transitive same-module closure) that
    resolve to anything under the ``jax`` package."""
    if id(fn) in seen:
        return
    seen.add(id(fn))
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                q = imports.resolve(node.id)
                if q == "jax" or (q or "").startswith("jax."):
                    yield node
                elif node.id in defs and id(defs[node.id]) not in seen:
                    yield from _jax_refs(defs[node.id], imports, defs, seen)


@register_rule(
    "jax-in-callback",
    description="host-callback functions (pure_callback/io_callback) must "
                "be jax-free, transitively — re-entering jax deadlocks the "
                "CPU client")
def jax_in_callback(mod: ModuleInfo, project: Project) -> Iterator[Finding]:
    imports = ImportMap(mod.tree)
    defs = _function_defs(mod.tree)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        if imports.qualified(node.func) not in _CALLBACK_FNS or not node.args:
            continue
        target = _callback_target(node.args[0], imports, defs)
        if target is None:
            continue  # dynamic callable: out of this rule's reach
        for ref in _jax_refs(target, imports, defs, set()):
            yield Finding(
                "jax-in-callback", mod.rel, ref.lineno, ref.col_offset,
                f"jax reference {ref.id!r} inside a function passed to a "  # type: ignore[attr-defined]
                f"host callback (line {node.lineno}); host callbacks must "
                f"stay numpy-only (switchsim/npfpisa mirrors) or the CPU "
                f"PJRT client deadlocks")


# ---------------------------------------------------------------------------
# DONATION-SAFETY — an argument donated to a jit must not be read after the
# call in the same scope (the serve/scheduler.py KV-pool pattern: donated
# pools are updated in place by XLA; the old buffer is garbage afterwards).
# ---------------------------------------------------------------------------


def _int_tuple(node: ast.AST) -> Tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.append(elt.value)
        return tuple(out)
    return ()


def _donating_defs(tree: ast.Module, imports: ImportMap) -> Dict[str, Tuple[int, ...]]:
    """name -> donated argnums, for @partial(jax.jit, donate_argnums=...)
    decorated defs and ``name = jax.jit(f, donate_argnums=...)`` bindings."""
    out: Dict[str, Tuple[int, ...]] = {}

    def donate_of(call: ast.Call) -> Tuple[int, ...]:
        if imports.qualified(call.func) not in ("jax.jit", "jit"):
            # @partial(jax.jit, ...) wraps the jit call one level out
            if imports.qualified(call.func) in ("functools.partial", "partial") \
                    and call.args \
                    and imports.qualified(call.args[0]) in ("jax.jit", "jit"):
                pass
            else:
                return ()
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                return _int_tuple(kw.value)
        return ()

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    nums = donate_of(dec)
                    if nums:
                        out[node.name] = nums
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            nums = donate_of(node.value)
            if nums:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        out[tgt.id] = nums
    return out


def _scope_statements(scope: ast.AST) -> List[ast.stmt]:
    """All statements of a function/module scope in source order, not
    descending into nested function/class scopes."""
    out: List[ast.stmt] = []

    def visit(body: List[ast.stmt]):
        for stmt in body:
            out.append(stmt)
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            for field in ("body", "orelse", "finalbody"):
                visit(getattr(stmt, field, []) or [])
            for handler in getattr(stmt, "handlers", []) or []:
                visit(handler.body)

    visit(scope.body)
    return out


def _assigned_names(stmt: ast.stmt) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(stmt):
        if isinstance(node, ast.Name) and isinstance(node.ctx, (ast.Store, ast.Del)):
            names.add(node.id)
    return names


def _enclosing_loop_body(stmts: List[ast.stmt], call_stmt: ast.stmt,
                         scope: ast.AST) -> Optional[List[ast.stmt]]:
    for node in ast.walk(scope):
        if isinstance(node, (ast.For, ast.While, ast.AsyncFor)):
            body = _scope_statements_of_loop(node)
            if call_stmt in body:
                return body
    return None


def _scope_statements_of_loop(loop: ast.AST) -> List[ast.stmt]:
    out: List[ast.stmt] = []

    def visit(body):
        for stmt in body:
            out.append(stmt)
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            for field in ("body", "orelse", "finalbody"):
                visit(getattr(stmt, field, []) or [])
            for handler in getattr(stmt, "handlers", []) or []:
                visit(handler.body)

    visit(loop.body)
    return out


@register_rule(
    "donation-safety",
    description="a buffer passed at a donate_argnums position must not be "
                "read after the call — XLA reuses its memory in place")
def donation_safety(mod: ModuleInfo, project: Project) -> Iterator[Finding]:
    imports = ImportMap(mod.tree)
    donating = _donating_defs(mod.tree, imports)
    if not donating:
        return
    from tools.repro_lint.astutil import walk_scopes

    for scope in walk_scopes(mod.tree):
        stmts = _scope_statements(scope)
        for idx, stmt in enumerate(stmts):
            for node in ast.walk(stmt):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)
                        and node.func.id in donating):
                    continue
                nums = donating[node.func.id]
                rebound = _assigned_names(stmt)
                tracked = {}
                for pos in nums:
                    if pos < len(node.args) and isinstance(node.args[pos], ast.Name):
                        nm = node.args[pos].id
                        if nm not in rebound:
                            tracked[nm] = node.lineno
                if not tracked:
                    continue
                # linear scan of following statements; inside a loop the
                # body wraps around (next iteration re-executes the top)
                following = stmts[idx + 1:]
                loop_body = _enclosing_loop_body(stmts, stmt, scope)
                if loop_body is not None:
                    pos_in_loop = loop_body.index(stmt)
                    following = (loop_body[pos_in_loop + 1:]
                                 + loop_body[:pos_in_loop]
                                 + [s for s in stmts[idx + 1:]
                                    if s not in loop_body])
                live = dict(tracked)
                for later in following:
                    if not live:
                        break
                    for n2 in ast.walk(later):
                        if isinstance(n2, ast.Name) and n2.id in live:
                            if isinstance(n2.ctx, (ast.Store, ast.Del)):
                                live.pop(n2.id, None)
                            else:
                                yield Finding(
                                    "donation-safety", mod.rel, n2.lineno,
                                    n2.col_offset,
                                    f"{n2.id!r} was donated to "
                                    f"{node.func.id}() on line "
                                    f"{live.pop(n2.id)} and read again "
                                    f"here — the donated buffer is dead "
                                    f"after the call (rebind it to the "
                                    f"call's result instead)")
                        if not live:
                            break


# ---------------------------------------------------------------------------
# FACADE-ONLY — no calls through the deprecated module-level allreduce shims
# or indexed strategy tables; every consumer constructs one Aggregator
# (PR 5's contract, today enforced only via DeprecationWarning at runtime).
# ---------------------------------------------------------------------------

_SHIM_MODULE = "repro.core.allreduce"
_SHIM_NAMES = {"allreduce", "allreduce_tree",
               "stacked_allreduce", "stacked_allreduce_tree"}
_FACADE_IMPL = {"src/repro/core/allreduce.py", "src/repro/core/agg.py"}
_STRATEGY_TABLES = {"STRATEGIES", "STACKED_STRATEGIES"}


@register_rule(
    "facade-only",
    description="no deprecated allreduce/stacked_allreduce shims or indexed "
                "STRATEGIES tables; construct an Aggregator (core/agg.py)")
def facade_only(mod: ModuleInfo, project: Project) -> Iterator[Finding]:
    if mod.rel in _FACADE_IMPL:
        return
    imports = ImportMap(mod.tree)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom) and node.module == _SHIM_MODULE:
            for a in node.names:
                if a.name in _SHIM_NAMES:
                    yield Finding(
                        "facade-only", mod.rel, node.lineno, node.col_offset,
                        f"importing deprecated shim "
                        f"{_SHIM_MODULE}.{a.name}; construct an "
                        f"Aggregator(AggConfig, axes) instead "
                        f"(repro.core.agg)")
        elif isinstance(node, ast.Call):
            q = imports.qualified(node.func)
            if q and q.startswith(_SHIM_MODULE + ".") \
                    and q.rsplit(".", 1)[1] in _SHIM_NAMES:
                yield Finding(
                    "facade-only", mod.rel, node.lineno, node.col_offset,
                    f"call through deprecated shim {q}(); use "
                    f"Aggregator.allreduce[_tree] (repro.core.agg)")
        elif isinstance(node, ast.Subscript):
            name = dotted(node.value)
            if name and name.split(".")[-1] in _STRATEGY_TABLES:
                yield Finding(
                    "facade-only", mod.rel, node.lineno, node.col_offset,
                    f"indexing removed strategy table {name}[...]; use "
                    f"repro.core.agg.get_strategy(name) / the registry")


# ---------------------------------------------------------------------------
# RNG-DISCIPLINE — no global-state numpy RNG; a seeded Generator (or jax
# PRNGKey) must be threaded explicitly so every run is reproducible across
# processes (the BENCH_*.json reproducibility contract).
# ---------------------------------------------------------------------------

_RNG_OK = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
           "PCG64", "PCG64DXSM", "Philox", "MT19937", "SFC64"}


@register_rule(
    "rng-discipline",
    description="no np.random global-state calls; thread an explicitly "
                "seeded np.random.Generator / jax PRNGKey")
def rng_discipline(mod: ModuleInfo, project: Project) -> Iterator[Finding]:
    imports = ImportMap(mod.tree)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        q = imports.qualified(node.func)
        if not q or not q.startswith("numpy.random."):
            continue
        fn = q.split(".")[-1]
        if fn in _RNG_OK:
            continue
        yield Finding(
            "rng-discipline", mod.rel, node.lineno, node.col_offset,
            f"np.random.{fn}() draws from numpy's hidden global RNG state; "
            f"create np.random.default_rng(seed) and pass the Generator "
            f"down so runs are reproducible across processes")


# ---------------------------------------------------------------------------
# TIMING-DISCIPLINE — every measurement on an instrumented phase path goes
# through time.perf_counter (monotonic, high-resolution) and every tracer
# span is a context manager. time.time() is wall-clock: NTP slews it and its
# resolution is platform-dependent, so durations computed from it are not
# trustworthy autotuner input; a bare Span.start() with a forgotten end
# corrupts the tracer's nesting stack (PR 10's contract, DESIGN.md §13).
# ---------------------------------------------------------------------------

_TIMING_SCOPE = (
    "src/repro/trace/*", "src/repro/autotune/*", "benchmarks/*",
    "src/repro/core/agg.py", "src/repro/core/bucketer.py",
    "src/repro/switchsim/*", "src/repro/serve/*", "src/repro/launch/*",
    "src/repro/runtime/controller.py",
)
# the tracer defines Span.start/.end — the one legitimate caller
_TIMING_IMPL = "src/repro/trace/tracer.py"


def _span_receiver(func: ast.Attribute) -> bool:
    """Heuristic: is ``<recv>.start()``'s receiver a tracer span?  True for
    a chained ``span(...).start()`` and for names that read like a span
    (``sp``, ``span``, ``outer_span`` …) — conservative enough to leave
    ``thread.start()`` / ``proc.start()`` alone."""
    recv = func.value
    if isinstance(recv, ast.Call):
        q = dotted(recv.func)
        return bool(q) and q.split(".")[-1] in ("span", "Span")
    name = dotted(recv)
    if not name:
        return False
    last = name.split(".")[-1].lower()
    return last == "sp" or "span" in last


@register_rule(
    "timing-discipline",
    scope=_TIMING_SCOPE,
    description="no time.time() on instrumented phase paths (perf_counter / "
                "benchmarks.common.timed) and no bare Span.start() — spans "
                "are context managers")
def timing_discipline(mod: ModuleInfo, project: Project) -> Iterator[Finding]:
    imports = ImportMap(mod.tree)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        q = imports.qualified(node.func)
        if q == "time.time":
            yield Finding(
                "timing-discipline", mod.rel, node.lineno, node.col_offset,
                "time.time() is wall-clock (NTP-slewed, platform-resolution) "
                "— durations from it are not valid span/autotuner input; use "
                "time.perf_counter() or benchmarks.common.timed()")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr == "start" and not node.args \
                and not node.keywords and mod.rel != _TIMING_IMPL \
                and _span_receiver(node.func):
            yield Finding(
                "timing-discipline", mod.rel, node.lineno, node.col_offset,
                "bare Span.start() — a forgotten end() corrupts the "
                "tracer's nesting stack; use the context-manager form "
                "'with trace.span(...) as sp:'")
