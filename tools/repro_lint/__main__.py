"""CLI entry point: ``python -m tools.repro_lint [paths...]``."""
import sys

from tools.repro_lint.engine import main

if __name__ == "__main__":
    sys.exit(main())
