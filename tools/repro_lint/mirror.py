"""MIRROR-PARITY — structural diff of the three dataplanes and the FPISA
numpy mirrors (project-level rule; runs once per lint).

The repo maintains the same switch semantics in three places on purpose
(DESIGN.md §10, kernels/README.md): the jitted ``switchsim/dataplane.py``,
its jax-free ``NumpyDataplane`` twin (host callbacks must not re-enter
jax), and the ``core/switch.py`` per-packet shim — plus pure-numpy FPISA
primitive mirrors in ``switchsim/npfpisa.py`` twinned with
``core/fpisa.py``, and the ``kernels/ref.py`` oracles twinned with the
Pallas kernels. Any drift between them historically showed up as parity
test failures hours later; this rule catches the structural half of the
drift at lint time:

* ``COUNTERS`` / ``SLOT_STATE_FIELDS`` are defined ONCE, in
  ``switchsim/__init__.py``, and only imported elsewhere;
* ``DataplaneState``'s fields == ``SLOT_STATE_FIELDS``; the ``_I_*`` counter
  index aliases cover every counter; ``NumpyDataplane`` carries a ``_f``
  attribute for every slot-state field ``f``;
* ``npfpisa.py`` defines the same mirror functions as ``core/fpisa.py`` and
  its hard-coded fp32 wire constants match ``core/numerics.py``'s FP32;
* every ``fused_*_ref`` oracle in ``kernels/ref.py`` has a same-named
  kernel in ``kernels/fpisa_fused.py``;
* the takeover-lottery (admission-rule) constants are defined in exactly
  one module.

Anchor files are located from the project root; a missing anchor file
skips its checks silently so the rule can be exercised on fixture trees.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from tools.repro_lint.astutil import literal_str_tuple
from tools.repro_lint.engine import Finding, Project, register_rule

INIT = "src/repro/switchsim/__init__.py"
DATAPLANE = "src/repro/switchsim/dataplane.py"
SWITCH_SHIM = "src/repro/core/switch.py"
NPFPISA = "src/repro/switchsim/npfpisa.py"
CORE_FPISA = "src/repro/core/fpisa.py"
NUMERICS = "src/repro/core/numerics.py"
KERNEL_REF = "src/repro/kernels/ref.py"
KERNEL_FUSED = "src/repro/kernels/fpisa_fused.py"

# the FPISA primitive mirror contract: these exist, same name, in BOTH
# core/fpisa.py (jnp) and switchsim/npfpisa.py (numpy)
MIRROR_FUNCS = ("encode", "renormalize", "fpisa_a_add", "fpisa_add_full")
# npfpisa's hard-coded fp32 wire constants, checked against numerics.FP32
WIRE_CONSTS = ("EXP_BITS", "MAN_BITS", "BIAS")
SHARED_CONSTS = ("COUNTERS", "SLOT_STATE_FIELDS")
LOTTERY_PREFIX = "_LOTTERY"


def _top_assigns(tree: ast.Module) -> Dict[str, ast.Assign]:
    """Top-level ``NAME = ...`` (incl. tuple-unpacking) -> Assign node."""
    out: Dict[str, ast.Assign] = {}
    for node in tree.body:
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                out[tgt.id] = node
            elif isinstance(tgt, ast.Tuple):
                for elt in tgt.elts:
                    if isinstance(elt, ast.Name):
                        out[elt.id] = node
    return out


def _top_defs(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body
            if isinstance(n, ast.FunctionDef)}


def _int_bindings(tree: ast.Module) -> Dict[str, int]:
    """Top-level integer constant bindings, following tuple unpacking
    (``A, B, C = 8, 23, 127``) and simple int literals."""
    out: Dict[str, int] = {}
    for node in tree.body:
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name) and isinstance(node.value, ast.Constant) \
                    and isinstance(node.value.value, int):
                out[tgt.id] = node.value.value
            elif isinstance(tgt, ast.Tuple) and isinstance(node.value, ast.Tuple) \
                    and len(tgt.elts) == len(node.value.elts):
                for name_n, val_n in zip(tgt.elts, node.value.elts):
                    if isinstance(name_n, ast.Name) \
                            and isinstance(val_n, ast.Constant) \
                            and isinstance(val_n.value, int):
                        out[name_n.id] = val_n.value
    return out


def _class(tree: ast.Module, name: str) -> Optional[ast.ClassDef]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _namedtuple_fields(cls: ast.ClassDef) -> Tuple[str, ...]:
    return tuple(stmt.target.id for stmt in cls.body
                 if isinstance(stmt, ast.AnnAssign)
                 and isinstance(stmt.target, ast.Name))


def _self_attr_stores(fn: ast.FunctionDef) -> Tuple[str, ...]:
    out: List[str] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and isinstance(node.ctx, ast.Store) \
                and isinstance(node.value, ast.Name) and node.value.id == "self":
            out.append(node.attr)
    return tuple(out)


@register_rule(
    "mirror-parity",
    project=True,
    description="the three dataplanes + numpy FPISA mirrors + kernel "
                "oracles stay structurally in sync (shared COUNTERS/"
                "slot-state constants, mirror functions, wire constants)")
def mirror_parity(project: Project) -> Iterator[Finding]:
    init = project.module_rel(INIT)
    dp = project.module_rel(DATAPLANE)

    # ---- shared constants live in switchsim/__init__.py ------------------
    counters: Optional[Tuple[str, ...]] = None
    slot_fields: Optional[Tuple[str, ...]] = None
    if init is not None:
        assigns = _top_assigns(init.tree)
        for const in SHARED_CONSTS:
            node = assigns.get(const)
            val = literal_str_tuple(node.value) if node is not None else None
            if val is None:
                yield Finding(
                    "mirror-parity", init.rel, 1, 0,
                    f"switchsim/__init__.py must define {const} as a "
                    f"literal tuple of strings — it is the single source "
                    f"of truth all three dataplanes import")
            elif const == "COUNTERS":
                counters = val
            else:
                slot_fields = val

    # ---- no duplicated literals in the mirror modules --------------------
    for rel in (DATAPLANE, SWITCH_SHIM, NPFPISA):
        mod = project.module_rel(rel)
        if mod is None:
            continue
        assigns = _top_assigns(mod.tree)
        for const in SHARED_CONSTS:
            node = assigns.get(const)
            if node is not None and literal_str_tuple(node.value) is not None:
                yield Finding(
                    "mirror-parity", mod.rel, node.lineno, node.col_offset,
                    f"{const} re-defined as a literal here; import it from "
                    f"repro.switchsim so the three dataplanes cannot drift")

    # ---- dataplane structural checks -------------------------------------
    if dp is not None and slot_fields is not None:
        state = _class(dp.tree, "DataplaneState")
        if state is not None:
            fields = _namedtuple_fields(state)
            if fields != slot_fields:
                missing = [f for f in slot_fields if f not in fields]
                extra = [f for f in fields if f not in slot_fields]
                yield Finding(
                    "mirror-parity", dp.rel, state.lineno, state.col_offset,
                    f"DataplaneState fields drifted from SLOT_STATE_FIELDS "
                    f"(missing: {missing or '-'}, extra: {extra or '-'}, "
                    f"or order differs); update switchsim/__init__.py and "
                    f"BOTH mirror dataplanes together")
        npdp = _class(dp.tree, "NumpyDataplane")
        if npdp is not None:
            init_fn = next((n for n in npdp.body
                            if isinstance(n, ast.FunctionDef)
                            and n.name == "__init__"), None)
            if init_fn is not None:
                attrs = set(_self_attr_stores(init_fn))
                for f in slot_fields:
                    if f"_{f}" not in attrs:
                        yield Finding(
                            "mirror-parity", dp.rel, init_fn.lineno,
                            init_fn.col_offset,
                            f"NumpyDataplane.__init__ does not initialize "
                            f"self._{f} — slot-state field {f!r} exists in "
                            f"the jitted dataplane but not the numpy "
                            f"mirror")
    if dp is not None and counters is not None:
        # the _I_* index alias unpacking must cover every counter
        for node in dp.tree.body:
            if not isinstance(node, ast.Assign):
                continue
            tgt = node.targets[0]
            if isinstance(tgt, ast.Tuple) and tgt.elts and all(
                    isinstance(e, ast.Name) and e.id.startswith("_I_")
                    for e in tgt.elts):
                if len(tgt.elts) != len(counters):
                    yield Finding(
                        "mirror-parity", dp.rel, node.lineno,
                        node.col_offset,
                        f"{len(tgt.elts)} _I_* counter index aliases vs "
                        f"{len(counters)} COUNTERS entries — a counter was "
                        f"added on one side only")

    # ---- lottery/admission constants defined exactly once ----------------
    lottery_homes = []
    for rel in (INIT, DATAPLANE, SWITCH_SHIM, NPFPISA):
        mod = project.module_rel(rel)
        if mod is None:
            continue
        names = [n for n in _top_assigns(mod.tree) if n.startswith(LOTTERY_PREFIX)]
        if names:
            lottery_homes.append((mod, names))
    if len(lottery_homes) > 1:
        for mod, names in lottery_homes[1:]:
            node = _top_assigns(mod.tree)[names[0]]
            yield Finding(
                "mirror-parity", mod.rel, node.lineno, node.col_offset,
                f"takeover-lottery constants {names} re-defined here as "
                f"well as in {lottery_homes[0][0].rel}; the admission "
                f"rules must share one constant set")

    # ---- FPISA primitive mirrors (core/fpisa.py <-> npfpisa.py) ----------
    npf = project.module_rel(NPFPISA)
    fp = project.module_rel(CORE_FPISA)
    if npf is not None and fp is not None:
        np_defs, fp_defs = _top_defs(npf.tree), _top_defs(fp.tree)
        for fn in MIRROR_FUNCS:
            for mod, defs, twin in ((npf, np_defs, fp.rel),
                                    (fp, fp_defs, npf.rel)):
                if fn not in defs:
                    yield Finding(
                        "mirror-parity", mod.rel, 1, 0,
                        f"mirror function {fn}() missing here but required "
                        f"by the numpy<->jnp FPISA mirror contract "
                        f"(twin: {twin})")
    nx = project.module_rel(NUMERICS)
    if npf is not None and nx is not None:
        want = _fp32_consts(nx.tree)
        have = _int_bindings(npf.tree)
        for name in WIRE_CONSTS:
            if name in want and name in have and want[name] != have[name]:
                yield Finding(
                    "mirror-parity", npf.rel, 1, 0,
                    f"npfpisa.{name} = {have[name]} but core/numerics.py "
                    f"FP32 implies {name} = {want[name]} — the numpy "
                    f"mirror no longer matches the wire format")

    # ---- kernel oracle twins (ref.py <-> fpisa_fused.py) ------------------
    ref = project.module_rel(KERNEL_REF)
    fused = project.module_rel(KERNEL_FUSED)
    if ref is not None and fused is not None:
        fused_defs = _top_defs(fused.tree)
        for name, node in _top_defs(ref.tree).items():
            if name.startswith("fused_") and name.endswith("_ref") \
                    and name[: -len("_ref")] not in fused_defs:
                yield Finding(
                    "mirror-parity", ref.rel, node.lineno, node.col_offset,
                    f"oracle {name}() has no same-named kernel "
                    f"{name[:-4]}() in kernels/fpisa_fused.py — oracle and "
                    f"kernel export sets drifted")


def _fp32_consts(tree: ast.Module) -> Dict[str, int]:
    """exp_bits/man_bits from ``FP32 = FpFormat(..., exp_bits=8,
    man_bits=23)``; bias derived the same way FpFormat.bias does."""
    for node in tree.body:
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name) and tgt.id == "FP32":
                kw = {k.arg: k.value.value for k in node.value.keywords
                      if isinstance(k.value, ast.Constant)
                      and isinstance(k.value.value, int)}
                if "exp_bits" in kw and "man_bits" in kw:
                    return {
                        "EXP_BITS": kw["exp_bits"],
                        "MAN_BITS": kw["man_bits"],
                        "BIAS": (1 << (kw["exp_bits"] - 1)) - 1,
                    }
    return {}
