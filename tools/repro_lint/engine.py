"""repro-lint engine: rule registry, suppressions, runner, output formats.

The registry mirrors the ``register_strategy`` idiom of ``repro.core.agg``:
rules self-register with capability metadata (scope predicate, file vs
project granularity) instead of being hard-wired into the runner, so a new
invariant plugs in with one decorator and is immediately reachable from the
CLI, the test harness, and CI.

Everything here is stdlib-only on purpose — the linter must run before (and
regardless of) the jax environment, e.g. as the first CI step.
"""
from __future__ import annotations

import ast
import dataclasses
import difflib
import fnmatch
import json
import re
import sys
import tokenize
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Finding",
    "ModuleInfo",
    "Project",
    "RuleSpec",
    "available_rules",
    "format_findings",
    "get_rule",
    "register_rule",
    "run_lint",
    "unregister_rule",
]


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a file:line."""

    rule: str
    path: str  # project-root-relative, posix separators
    line: int
    col: int
    message: str

    def key(self) -> Tuple[str, str, int]:
        return (self.rule, self.path, self.line)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# parsed-module / project context
# ---------------------------------------------------------------------------


class ModuleInfo:
    """One parsed source file: path, text, AST, per-line suppressions."""

    def __init__(self, root: Path, path: Path):
        self.abspath = path
        self.rel = path.relative_to(root).as_posix()
        self.source = path.read_text(encoding="utf-8")
        self.tree = ast.parse(self.source, filename=str(path))
        self.lines = self.source.splitlines()
        self._line_disable, self._file_disable = _parse_suppressions(
            self.source)

    def suppressed(self, rule: str, line: int) -> bool:
        rule = rule.lower()
        for names in (self._file_disable,
                      self._line_disable.get(line, ()),
                      # a comment-only line suppresses the line below it
                      self._line_disable.get(line - 1, ())
                      if _comment_only(self.lines, line - 1) else ()):
            if "all" in names or rule in names:
                return True
        return False


def _comment_only(lines: Sequence[str], lineno: int) -> bool:
    if not 1 <= lineno <= len(lines):
        return False
    return lines[lineno - 1].lstrip().startswith("#")


_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable(?:-file)?)\s*=\s*([A-Za-z0-9_\-]+"
    r"(?:\s*,\s*[A-Za-z0-9_\-]+)*)")


def _parse_suppressions(source: str):
    """Token-level scan (comments only, so suppression directives inside
    string literals — e.g. lint-test fixtures — do not leak)."""
    line_disable: Dict[int, Tuple[str, ...]] = {}
    file_disable: List[str] = []
    try:
        tokens = tokenize.generate_tokens(iter(source.splitlines(True)).__next__)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if not m:
                continue
            names = tuple(n.strip().lower() for n in m.group(2).split(","))
            if m.group(1) == "disable-file":
                file_disable.extend(names)
            else:
                prev = line_disable.get(tok.start[0], ())
                line_disable[tok.start[0]] = prev + names
    except tokenize.TokenError:
        pass
    return line_disable, tuple(file_disable)


class Project:
    """Lint run context: the project root plus a parse cache, so project-
    level rules (mirror parity) and file rules share one AST per file."""

    def __init__(self, root: Path):
        self.root = Path(root).resolve()
        self._cache: Dict[Path, Optional[ModuleInfo]] = {}

    def module(self, path: Path) -> Optional[ModuleInfo]:
        """Parse (cached); returns None for unreadable/unparsable files —
        syntax errors are reported by the runner, not by rules."""
        path = path.resolve()
        if path not in self._cache:
            try:
                self._cache[path] = ModuleInfo(self.root, path)
            except (OSError, SyntaxError, ValueError):
                self._cache[path] = None
        return self._cache[path]

    def module_rel(self, rel: str) -> Optional[ModuleInfo]:
        p = self.root / rel
        return self.module(p) if p.is_file() else None


# ---------------------------------------------------------------------------
# rule registry (the register_strategy idiom)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RuleSpec:
    """One registered invariant rule.

    ``check`` takes ``(module, project)`` for file rules and ``(project,)``
    for project rules, yielding ``Finding``s. ``scope`` is a sequence of
    glob patterns matched against the project-relative path (empty = every
    linted file)."""

    name: str
    check: Callable
    scope: Tuple[str, ...] = ()
    project: bool = False  # True: run once per lint run, not per file
    description: str = ""

    def in_scope(self, rel: str) -> bool:
        if not self.scope:
            return True
        return any(fnmatch.fnmatch(rel, pat) for pat in self.scope)


_RULES: Dict[str, RuleSpec] = {}


def register_rule(name: str, *, scope: Sequence[str] = (),
                  project: bool = False, description: str = "",
                  overwrite: bool = False):
    """Decorator registering a rule under ``name`` (kebab-case id used in
    reports and ``# repro-lint: disable=`` comments).

        @register_rule("exact-scale", scope=("src/repro/core/*",),
                       description="no inexact pow2 on scale paths")
        def check(module, project): ...

    Re-registering requires ``overwrite=True`` (two plugins colliding should
    fail loudly, same contract as the aggregation strategy registry)."""

    def deco(fn: Callable) -> Callable:
        if name in _RULES and not overwrite:
            raise ValueError(
                f"lint rule {name!r} is already registered "
                f"(pass overwrite=True to replace it)")
        _RULES[name] = RuleSpec(
            name=name, check=fn, scope=tuple(scope), project=project,
            description=description or (fn.__doc__ or "").split("\n")[0])
        return fn

    return deco


def unregister_rule(name: str) -> None:
    _RULES.pop(name, None)


def _ensure_builtin() -> None:
    if "facade-only" not in _RULES:
        from tools.repro_lint import mirror, rules  # noqa: F401


def available_rules() -> Tuple[str, ...]:
    _ensure_builtin()
    return tuple(sorted(_RULES))


def get_rule(name: str) -> RuleSpec:
    _ensure_builtin()
    try:
        return _RULES[name]
    except KeyError:
        close = difflib.get_close_matches(name, sorted(_RULES), n=1)
        hint = f"; did you mean {close[0]!r}?" if close else ""
        raise ValueError(
            f"unknown lint rule {name!r}; registered rules: "
            f"{', '.join(sorted(_RULES))}{hint}") from None


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------


def _iter_py_files(paths: Sequence[Path]) -> Iterable[Path]:
    seen = set()
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            files: Iterable[Path] = (p,)
        elif p.is_dir():
            files = sorted(p.rglob("*.py"))
        else:
            files = ()
        for f in files:
            if "__pycache__" in f.parts:
                continue
            f = f.resolve()
            if f not in seen:
                seen.add(f)
                yield f


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]
    suppressed: List[Finding]
    errors: List[str]  # unparsable files
    checked: int
    rules: Tuple[str, ...]

    @property
    def clean(self) -> bool:
        return not self.findings and not self.errors

    def to_dict(self) -> dict:
        return {
            "version": 1,
            "clean": self.clean,
            "checked_files": self.checked,
            "rules": list(self.rules),
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "errors": list(self.errors),
        }


def run_lint(paths: Sequence[str | Path], *, root: str | Path | None = None,
             rules: Sequence[str] | None = None) -> LintResult:
    """Lint ``paths`` (files or directories, relative to ``root``/cwd).

    File rules run per parsed module in their scope; project rules run once
    against the project root (they locate their anchor files themselves and
    stay silent when the anchors do not exist — a fixture tree exercises
    them by reproducing the layout). Findings carry root-relative paths;
    suppression comments in the *target* file filter them."""
    _ensure_builtin()
    root_path = Path(root).resolve() if root else Path.cwd()
    project = Project(root_path)
    names = tuple(rules) if rules else available_rules()
    specs = [get_rule(n) for n in names]

    raw: List[Finding] = []
    errors: List[str] = []
    checked = 0
    for path in _iter_py_files([root_path / p for p in map(str, paths)]):
        try:
            rel = path.relative_to(root_path).as_posix()
        except ValueError:
            rel = path.as_posix()
        mod = project.module(path)
        if mod is None:
            errors.append(f"{rel}: unreadable or not valid Python")
            continue
        checked += 1
        for spec in specs:
            if spec.project or not spec.in_scope(rel):
                continue
            raw.extend(spec.check(mod, project))
    for spec in specs:
        if spec.project:
            raw.extend(spec.check(project))

    findings, suppressed = [], []
    for f in sorted(set(raw), key=lambda f: (f.path, f.line, f.rule)):
        mod = project.module_rel(f.path)
        if mod is not None and mod.suppressed(f.rule, f.line):
            suppressed.append(f)
        else:
            findings.append(f)
    return LintResult(findings=findings, suppressed=suppressed,
                      errors=errors, checked=checked, rules=names)


def format_findings(result: LintResult, fmt: str = "human") -> str:
    if fmt == "json":
        return json.dumps(result.to_dict(), indent=2, sort_keys=True)
    out = []
    for f in result.findings:
        out.append(f"{f.path}:{f.line}:{f.col}: {f.rule}: {f.message}")
    out.extend(f"error: {e}" for e in result.errors)
    tail = (f"{len(result.findings)} finding(s), "
            f"{len(result.suppressed)} suppressed, "
            f"{result.checked} file(s) checked")
    out.append(("clean: " if result.clean else "FAIL: ") + tail)
    return "\n".join(out)


def main(argv: Sequence[str] | None = None) -> int:
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description="AST-based invariant linter for the FPISA repro "
                    "(bit-identity, mirror parity, donation safety, ...)")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files/directories to lint (default: src)")
    parser.add_argument("--format", choices=("human", "json"),
                        default="human")
    parser.add_argument("--rules", default=None, metavar="R1,R2",
                        help="comma-separated subset of rules to run")
    parser.add_argument("--root", default=None,
                        help="project root (default: cwd); findings and "
                             "scopes are relative to it")
    parser.add_argument("--list-rules", action="store_true")
    parser.add_argument("--output", default=None, metavar="FILE",
                        help="also write the report (in --format) to FILE")
    ns = parser.parse_args(argv)

    if ns.list_rules:
        for name in available_rules():
            spec = get_rule(name)
            kind = "project" if spec.project else "file"
            print(f"{name:18s} [{kind}] {spec.description}")
        return 0

    rules = [r.strip() for r in ns.rules.split(",")] if ns.rules else None
    try:
        result = run_lint(ns.paths, root=ns.root, rules=rules)
    except ValueError as e:  # unknown rule name
        print(f"repro-lint: {e}", file=sys.stderr)
        return 2
    report = format_findings(result, ns.format)
    print(report)
    if ns.output:
        Path(ns.output).write_text(report + "\n", encoding="utf-8")
    return 0 if result.clean else 1
