"""Quickstart: FPISA in 60 seconds.

1. Encode a gradient tensor into switch-register integer planes.
2. Aggregate 8 workers three ways: exact float, bit-faithful FPISA-A (switch
   arrival semantics), and the production block-integer path (order-invariant).
3. Show the paper's headline numerics: tiny error, bounded overwrite events,
   bit-exact reproducibility for the production path.

The production path honors the same shared knobs as every launch CLI
(repro.core.agg.add_agg_args — launch/train.py, launch/dryrun.py, serve_lm):
  --agg-backend {auto,jnp,pallas}   encode/decode transform backend
  --agg-chunk N                     stream the gradient in N-element chunks
  --bucket-bytes N                  bucketed whole-pytree aggregation (step 4)

Run:  PYTHONPATH=src python examples/quickstart.py [--agg-backend jnp]
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import fpisa as F
from repro.core import numerics as nx
from repro.core.agg import add_agg_args, resolve_backend
from repro.kernels import fpisa_fused
from repro.trace import add_trace_args
from repro.trace import from_args as trace_from_args

ap = argparse.ArgumentParser()
add_agg_args(ap)  # the same shared --agg-* flags every entry point uses
add_trace_args(ap)  # the shared --trace-* flags (repro.trace)
ap.set_defaults(bucket_bytes=1 << 16)  # step 4's whole-pytree demo
args = ap.parse_args()
backend = resolve_backend(args.agg_backend)
session = trace_from_args(args)  # spans from step 4's Aggregator calls

rng = np.random.default_rng(0)
W, N, BLOCK = 8, 1 << 16, 256
grads = (rng.standard_normal((W, N)) * 0.01).astype(np.float32)

# --- 1. the representation (paper Fig. 3) ---
planes = F.encode(jnp.asarray(grads[0]))
print(f"FP32 value {grads[0,0]:+.6f} -> exp={int(planes.exp[0])} "
      f"man={int(planes.man[0])} (two's-complement, 7 headroom bits)")
roundtrip = F.renormalize(planes)
assert np.array_equal(np.asarray(roundtrip), grads[0])
print("encode -> delayed-renormalize roundtrip: bit-exact")

# --- 2. aggregation three ways ---
exact = grads.astype(np.float64).sum(0)

seq, stats = F.fpisa_sum_sequential(jnp.asarray(grads), return_stats=True)
err = np.abs(np.asarray(seq, np.float64) - exact)
print(f"\nFPISA-A (switch arrival order): p50 err {np.quantile(err,0.5):.2e}, "
      f"p99 {np.quantile(err,0.99):.2e}, overwrites {int(stats['overwrite'])} "
      f"of {W*N} adds (paper: rare, <0.9%)")


# production block-integer path (what the training framework uses), on the
# selected transform backend, optionally streamed chunk by chunk
def block_aggregate(chunk: np.ndarray) -> jnp.ndarray:
    """chunk: (W, M) with M % BLOCK == 0 -> aggregated (M,) float32."""
    s = nx.required_preshift(W)
    if backend == "pallas":
        # fused single-pass kernels (interpret mode off-TPU), local block max
        # + exact residual shift to the cross-worker max — bit-identical to
        # the jnp formulation (shift composition, see kernels/README.md)
        interp = jax.default_backend() != "tpu"
        mans, bmaxs = zip(*(fpisa_fused.fused_encode_align(
            jnp.asarray(chunk[w]).reshape(-1, BLOCK),
            interpret=interp) for w in range(W)))
        bmax = jnp.max(jnp.stack(bmaxs), axis=0)
        man = jnp.stack([
            nx.arshift(m, (bmax - bm)[:, None] + s) for m, bm in zip(mans, bmaxs)])
        man_sum = man.sum(0)
        return fpisa_fused.fused_decode(
            man_sum, bmax, preshift=s, interpret=interp).reshape(-1)
    p = F.encode(jnp.asarray(chunk).reshape(-1))
    pe = p.exp.reshape(W, chunk.shape[1])
    bmax = jnp.max(F.block_max_exponent(pe, BLOCK), axis=0)  # "pmax across workers"
    man = jnp.stack([F.block_encode(jnp.asarray(chunk[w]), bmax, BLOCK, s)
                     for w in range(W)])
    man_sum = man.sum(0)  # "integer psum" — associative, reproducible
    return F.block_decode(man_sum, bmax, BLOCK, s)


chunk = args.agg_chunk or N
assert chunk % BLOCK == 0, "--agg-chunk must be a multiple of 256"
out = jnp.concatenate([block_aggregate(grads[:, lo:lo + chunk])
                       for lo in range(0, N, chunk)])
err2 = np.abs(np.asarray(out, np.float64) - exact)
print(f"FPISA block-integer psum [{backend}"
      f"{', chunked' if args.agg_chunk else ''}]: "
      f"p99 err {np.quantile(err2,0.99):.2e}")

perm = rng.permutation(W)
out2 = jnp.concatenate([block_aggregate(grads[perm][:, lo:lo + chunk])
                        for lo in range(0, N, chunk)])
print("permutation-invariant bit-exact:", bool(jnp.all(out == out2)),
      "(float sums are NOT — this is the production win)")

# --- 4. bucketed whole-pytree aggregation (what --bucket-bytes turns on) ---
# The trainer never aggregates one tensor: it aggregates a pytree of ragged
# leaves. Per-leaf dispatch pays the encode/decode overhead per LEAF;
# bucketing flattens the tree into fixed-size block-aligned wire buckets
# (a block never spans two leaves), streams them double-buffered, and stays
# bit-identical. See core/bucketer.py and DESIGN.md §3.
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.agg import AggConfig, Aggregator

mesh = compat.make_mesh((jax.device_count(),), ("data",))
tree = {f"layer{i}": jnp.asarray(
    (rng.standard_normal(n) * 0.01).astype(np.float32))
    for i, n in enumerate((4096, 700, 13 * 37, 2048, 5))}


def agg_tree(bucket_bytes: int):
    agg = Aggregator(AggConfig(strategy="fpisa", backend=args.agg_backend,
                               bucket_bytes=bucket_bytes), ("data",))
    fn = compat.shard_map(
        agg.allreduce_tree, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), tree),),
        out_specs=jax.tree.map(lambda _: P(), tree), check_vma=False)
    return jax.jit(fn)(tree)


per_leaf, bucketed = agg_tree(0), agg_tree(args.bucket_bytes)
same = all(bool(jnp.all(per_leaf[k].view(jnp.int32) == bucketed[k].view(jnp.int32)))
           for k in tree)
print(f"\nbucketed tree aggregation ({args.bucket_bytes} B buckets) "
      f"bit-identical to per-leaf: {same}")
session.finish()
