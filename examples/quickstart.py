"""Quickstart: FPISA in 60 seconds.

1. Encode a gradient tensor into switch-register integer planes.
2. Aggregate 8 workers three ways: exact float, bit-faithful FPISA-A (switch
   arrival semantics), and the production block-integer path (order-invariant).
3. Show the paper's headline numerics: tiny error, bounded overwrite events,
   bit-exact reproducibility for the production path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import fpisa as F
from repro.core import numerics as nx

rng = np.random.default_rng(0)
W, N = 8, 1 << 16
grads = (rng.standard_normal((W, N)) * 0.01).astype(np.float32)

# --- 1. the representation (paper Fig. 3) ---
planes = F.encode(jnp.asarray(grads[0]))
print(f"FP32 value {grads[0,0]:+.6f} -> exp={int(planes.exp[0])} "
      f"man={int(planes.man[0])} (two's-complement, 7 headroom bits)")
roundtrip = F.renormalize(planes)
assert np.array_equal(np.asarray(roundtrip), grads[0])
print("encode -> delayed-renormalize roundtrip: bit-exact")

# --- 2. aggregation three ways ---
exact = grads.astype(np.float64).sum(0)

seq, stats = F.fpisa_sum_sequential(jnp.asarray(grads), return_stats=True)
err = np.abs(np.asarray(seq, np.float64) - exact)
print(f"\nFPISA-A (switch arrival order): p50 err {np.quantile(err,0.5):.2e}, "
      f"p99 {np.quantile(err,0.99):.2e}, overwrites {int(stats['overwrite'])} "
      f"of {W*N} adds (paper: rare, <0.9%)")

# production block-integer path (what the training framework uses)
p = F.encode(jnp.asarray(grads).reshape(-1))
pe = p.exp.reshape(W, N)
bmax = jnp.max(F.block_max_exponent(pe, 256), axis=0)  # "pmax across workers"
s = nx.required_preshift(W)
man = jnp.stack([F.block_encode(jnp.asarray(grads[w]), bmax, 256, s) for w in range(W)])
man_sum = man.sum(0)  # "integer psum" — associative, reproducible
out = F.block_decode(man_sum, bmax, 256, s)
err2 = np.abs(np.asarray(out, np.float64) - exact)
print(f"FPISA block-integer psum:       p99 err {np.quantile(err2,0.99):.2e}")

perm = rng.permutation(W)
man_sum2 = man[perm].sum(0)
out2 = F.block_decode(man_sum2, bmax, 256, s)
print("permutation-invariant bit-exact:", bool(jnp.all(out == out2)),
      "(float sums are NOT — this is the production win)")
