"""Batched serving example: prefill + greedy decode over a request queue
using the ServeEngine (static batching, per-slot KV caches).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.models.registry import build, param_count
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_smoke_config("internlm2-20b").with_(num_layers=4, d_model=128,
                                                  num_heads=8, num_kv_heads=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {param_count(params)/1e6:.1f}M params")

    eng = ServeEngine(model, params, batch_size=4, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, rng.integers(4, 20)).astype(np.int32),
                max_new_tokens=16)
        for i in range(8)
    ]
    t0 = time.time()
    results = eng.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results)
    print(f"{len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. compile)")
    for r in results[:3]:
        print(f"  rid={r.rid} -> {r.tokens[:8].tolist()}...")


if __name__ == "__main__":
    main()
