"""Serving example: greedy decode over a Poisson request trace with either
engine — ``--engine static`` (lockstep batches, dense per-slot KV) or
``--engine continuous`` (continuous batching over the paged KV cache,
repro.serve.scheduler). Both see the same load-generated workload and both
aggregate their serving telemetry across the data axis through the same
Aggregator facade the trainers use (the shared ``--agg-*`` flags) — one
aggregation surface for the whole repo.

Run:  PYTHONPATH=src python examples/serve_lm.py [--agg-strategy fpisa]
      PYTHONPATH=src python examples/serve_lm.py --smoke --engine continuous
"""
import argparse
from time import perf_counter

import jax

from repro.configs import get_smoke_config
from repro.core.agg import AggConfig, add_agg_args
from repro.models.registry import build, param_count
from repro.serve.engine import ServeEngine
from repro.serve.loadgen import PoissonLoadGen, latency_report
from repro.serve.scheduler import ContinuousEngine
from repro.trace import add_trace_args
from repro.trace import from_args as trace_from_args


def main():
    ap = argparse.ArgumentParser()
    add_agg_args(ap)  # the shared --agg-* flags (repro.core.agg)
    add_trace_args(ap)  # the shared --trace-* flags (repro.trace)
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="static", help="serving engine to demo")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + short trace (CI serve-smoke size)")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace length (default 8, smoke 6)")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrival rate, requests per scheduler step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    try:
        agg = AggConfig.from_args(args)
    except ValueError as e:
        ap.error(str(e))

    cfg = get_smoke_config("internlm2-20b").with_(num_layers=4, d_model=128,
                                                  num_heads=8, num_kv_heads=2)
    slots, max_len, page = 4, 128, 16
    n_req, prompt_lens, max_new = 8, (4, 8, 16), (8, 16)
    if args.smoke:
        cfg = cfg.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2)
        slots, max_len, page = 3, 32, 8
        n_req, prompt_lens, max_new = 6, (4, 8), (4, 8)
    if args.requests is not None:
        n_req = args.requests

    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {param_count(params)/1e6:.1f}M params, "
          f"engine={args.engine}, telemetry agg={agg.strategy}")

    lg = PoissonLoadGen(rate=args.rate, prompt_lens=prompt_lens,
                        max_new=max_new, vocab_size=cfg.vocab_size,
                        seed=args.seed)
    trace = lg.trace(n_req)

    session = trace_from_args(args)
    t0 = perf_counter()
    if args.engine == "continuous":
        eng = ContinuousEngine(model, params, num_slots=slots,
                               max_len=max_len, page_size=page, agg=agg)
        results = eng.run_trace(trace)
    else:
        # static engine serves the same requests as one closed queue (it has
        # no notion of arrival times — every request is present up front)
        eng = ServeEngine(model, params, batch_size=slots, max_len=max_len,
                          agg=agg)
        results = eng.run([r for _, r in trace])
    dt = perf_counter() - t0
    session.finish()

    total_new = sum(len(r.tokens) for r in results)
    print(f"{n_req} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. compile)")
    if args.engine == "continuous":
        rep = latency_report(eng.latency_stats(), slo_ttft=2 * slots,
                             slo_tpot=1.5)
        print("latency (scheduler-step units): " +
              ", ".join(f"{k}={v:.2f}" for k, v in rep.items()))
        print(f"paged KV peak: {eng.cache.peak_pages_in_use} pages "
              f"({eng.cache.peak_pages_in_use * page} tok) vs dense "
              f"{eng.cache.dense_equivalent_tokens} tok")
    print(f"telemetry (aggregated via {eng.aggregator}): {eng.telemetry}")
    for r in results[:3]:
        print(f"  rid={r.rid} -> {r.tokens[:8].tolist()}...")


if __name__ == "__main__":
    main()
