"""Batched serving example: prefill + greedy decode over a request queue
using the ServeEngine (static batching, per-slot KV caches).

The engine takes the same shared ``--agg-*`` flags as the training CLIs
(repro.core.agg.add_agg_args): per-batch serving telemetry is aggregated
across the data axis through the same Aggregator facade the trainers use —
one aggregation surface for the whole repo.

Run:  PYTHONPATH=src python examples/serve_lm.py [--agg-strategy fpisa]
"""
import argparse
import time

import numpy as np

import jax

from repro.configs import get_smoke_config
from repro.core.agg import AggConfig, add_agg_args
from repro.models.registry import build, param_count
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    add_agg_args(ap)  # the shared --agg-* flags (repro.core.agg)
    args = ap.parse_args()
    try:
        agg = AggConfig.from_args(args)
    except ValueError as e:
        ap.error(str(e))

    cfg = get_smoke_config("internlm2-20b").with_(num_layers=4, d_model=128,
                                                  num_heads=8, num_kv_heads=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {param_count(params)/1e6:.1f}M params, "
          f"telemetry agg={agg.strategy}")

    eng = ServeEngine(model, params, batch_size=4, max_len=128, agg=agg)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, rng.integers(4, 20)).astype(np.int32),
                max_new_tokens=16)
        for i in range(8)
    ]
    t0 = time.time()
    results = eng.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results)
    print(f"{len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. compile)")
    print(f"telemetry (aggregated via {eng.aggregator}): {eng.telemetry}")
    for r in results[:3]:
        print(f"  rid={r.rid} -> {r.tokens[:8].tolist()}...")


if __name__ == "__main__":
    main()
