"""Distributed FP query processing with in-switch FPISA operators (paper
Sec. 6): Top-N pruning and group-by aggregation on a Big-Data-bench-like
uservisits table, vs a Spark-like full-scan baseline.

Run:  PYTHONPATH=src python examples/query_processing.py
"""
import time

import numpy as np

from repro.db import query as q


def main():
    rng = np.random.default_rng(1)
    rows = 100_000
    ad_revenue = rng.gamma(2.0, 50.0, rows).astype(np.float32)
    country = rng.integers(0, 32, rows)

    print(f"uservisits: {rows:,} rows, FP32 adRevenue column\n")

    # SELECT TOP 10 adRevenue  (in-switch pruning, FPISA comparison)
    t0 = time.time()
    pruner = q.TopNPruner(n=10)
    surv = pruner.run(ad_revenue, batch=4096)
    top10 = np.sort(ad_revenue[surv])[::-1][:10]
    t_sw = time.time() - t0
    exact = q.spark_like_topn(ad_revenue, 10)
    assert np.array_equal(top10, exact)
    print(f"Top-10: switch pruned {pruner.stats.prune_rate:.1%} of the stream "
          f"({pruner.stats.rows_out:,} rows reached the master) — exact result")

    # SELECT country, SUM(adRevenue) GROUP BY country (in-switch aggregation)
    sub = slice(0, 20000)
    agg = q.GroupBySum(num_slots=32, variant="full")
    got = agg.run(country[sub], ad_revenue[sub])
    exact_g = q.spark_like_groupby(country[sub], ad_revenue[sub])
    worst = max(abs(got[k] - v) / v for k, v in exact_g.items())
    print(f"Group-by SUM: only {agg.stats.rows_out} aggregates left the switch "
          f"(from {agg.stats.rows_in:,} rows); worst rel err {worst:.2e}")
    print("\npaper claim: 1.9-2.7x over Spark from exactly this data reduction")


if __name__ == "__main__":
    main()
