"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
FPISA gradient aggregation, checkpointing, and automatic restart.

Defaults are sized for this CPU container (~100M params, 300 steps). On a
real pod, point --arch at a full config and raise the batch.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.configs import get_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--agg", default="fpisa",
                    choices=["native", "fpisa", "switchml", "fpisa_seq",
                             "switch_emu"])
    ap.add_argument("--agg-backend", default="auto",
                    choices=["auto", "jnp", "pallas"],
                    help="pre/post-collective transform backend (matches "
                         "launch/train.py: fused Pallas kernels on TPU)")
    ap.add_argument("--agg-chunk", type=int, default=0,
                    help="stream the aggregation through chunks of this many "
                         "elements (0 = whole-tensor)")
    ap.add_argument("--bucket-bytes", type=int, default=0,
                    help="stream the gradient pytree through fixed-size "
                         "block-aligned wire buckets (core/bucketer.py; "
                         "bit-identical to per-leaf; 0 = per-leaf)")
    ap.add_argument("--ckpt-dir", default="/tmp/fpisa_train_lm")
    args = ap.parse_args()

    # ~100M-param qwen-family config (20 layers x 640 wide, 32k vocab)
    cfg = get_config("qwen1.5-0.5b").with_(
        name="qwen-100m", num_layers=20, d_model=640, num_heads=10,
        num_kv_heads=10, d_ff=1792, vocab_size=32768,
        param_dtype="float32", activation_dtype="float32",
        attn_q_chunk=256, learning_rate=3e-4,
    )
    params, opt, hist = train_loop(
        cfg, steps=args.steps, global_batch=8, seq_len=256,
        agg_strategy=args.agg, agg_backend=args.agg_backend,
        agg_chunk=args.agg_chunk, agg_bucket_bytes=args.bucket_bytes,
        ckpt_dir=args.ckpt_dir, ckpt_every=50,
        log_every=10,
    )
    print(f"final loss {hist[-1]:.4f} (from {hist[0]:.4f}); "
          f"resume supported via --ckpt-dir (re-run to continue)")


if __name__ == "__main__":
    main()
