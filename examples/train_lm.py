"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
FPISA gradient aggregation, checkpointing, and automatic restart.

Defaults are sized for this CPU container (~100M params, 300 steps). On a
real pod, point --arch at a full config and raise the batch.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.configs import get_config
from repro.core.agg import AggConfig, add_agg_args
from repro.launch.train import train_loop
from repro.trace import add_trace_args
from repro.trace import from_args as trace_from_args


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny smoke-size config instead of the ~100M model "
                         "(CI examples-smoke job)")
    add_agg_args(ap)  # the shared --agg-* flags (repro.core.agg)
    add_trace_args(ap)  # the shared --trace-* flags (repro.trace)
    ap.add_argument("--ckpt-dir", default=None,
                    help="default /tmp/fpisa_train_lm (normal path) or "
                         "/tmp/fpisa_train_lm_fault (--fault-plan path: the "
                         "elastic controller resets its checkpoint dir at "
                         "start, so the two paths must not share one)")
    ap.add_argument("--fault-plan", default="",
                    help="inject failures and recover elastically, e.g. "
                         "'kill:2@40' kills host 2 at step 40: the elastic "
                         "controller reclaims its switch slots, re-meshes the "
                         "survivors and resumes bit-identically "
                         "(repro/runtime/controller.py)")
    ap.add_argument("--num-hosts", type=int, default=None,
                    help="logical worker count for the controller path "
                         "(default: one per device)")
    args = ap.parse_args()

    if args.smoke:
        from repro.configs import get_smoke_config

        cfg = get_smoke_config("qwen1.5-0.5b")
    else:
        # ~100M-param qwen-family config (20 layers x 640 wide, 32k vocab)
        cfg = get_config("qwen1.5-0.5b").with_(
            name="qwen-100m", num_layers=20, d_model=640, num_heads=10,
            num_kv_heads=10, d_ff=1792, vocab_size=32768,
            param_dtype="float32", activation_dtype="float32",
            attn_q_chunk=256, learning_rate=3e-4,
        )
    try:
        agg = AggConfig.from_args(args)
    except ValueError as e:
        ap.error(str(e))
    session = trace_from_args(args)
    try:
        _run(ap, args, cfg, agg)
    finally:
        session.finish()


def _run(ap, args, cfg, agg):
    if args.fault_plan or args.num_hosts:
        if agg.chunk_elems:
            ap.error("--agg-chunk is not supported on the elastic controller "
                     "path (stacked aggregation; use --bucket-bytes instead)")
        from repro.runtime.controller import run_controller

        summary = run_controller(
            cfg, steps=args.steps, global_batch=8, seq_len=256,
            agg=agg, num_hosts=args.num_hosts,
            ckpt_dir=args.ckpt_dir or "/tmp/fpisa_train_lm_fault",
            fault_plan=args.fault_plan)
        hist = summary["history"]
        print(f"final loss {hist[-1]:.4f} (from {hist[0]:.4f}); "
              f"{len(summary['recoveries'])} recoveries, "
              f"switch slots reclaimed: "
              f"{sum(r['reclaimed'] for r in summary['recoveries'])}")
        return
    params, opt, hist = train_loop(
        cfg, steps=args.steps, global_batch=8,
        seq_len=64 if args.smoke else 256, agg=agg,
        ckpt_dir=args.ckpt_dir or (
            "/tmp/fpisa_train_lm_smoke" if args.smoke else "/tmp/fpisa_train_lm"),
        ckpt_every=50, log_every=10,
    )
    print(f"final loss {hist[-1]:.4f} (from {hist[0]:.4f}); "
          f"resume supported via --ckpt-dir (re-run to continue)")


if __name__ == "__main__":
    main()
