"""End-to-end integration: training loop with checkpoint/restart determinism,
serving engine, and elastic mesh resume (subprocess for multi-device parts)."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.launch.train import train_loop
from repro.models.registry import build
from repro.serve.engine import Request, ServeEngine


def test_train_loop_learns_and_checkpoints(tmp_path):
    cfg = get_smoke_config("stablelm-3b").with_(num_layers=2, d_model=64)
    params, opt, hist = train_loop(
        cfg, steps=24, global_batch=4, seq_len=64, agg_strategy="native",
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=10, log_every=100,
        opt_overrides={"lr": 3e-3, "warmup_steps": 4},
    )
    assert hist[-1] < hist[0], hist
    from repro.runtime import checkpoint as ckpt

    assert ckpt.latest_step(str(tmp_path / "ck")) == 20


def test_train_resume_continues_identically(tmp_path):
    cfg = get_smoke_config("stablelm-3b").with_(num_layers=2, d_model=64)
    kw = dict(global_batch=4, seq_len=64, agg_strategy="native", log_every=100,
              opt_overrides={"lr": 1e-3, "warmup_steps": 4})
    # uninterrupted run
    _, _, full = train_loop(cfg, steps=16, **kw)
    # interrupted at 10 (checkpoint), then resumed
    d = str(tmp_path / "ck2")
    train_loop(cfg, steps=11, ckpt_dir=d, ckpt_every=10, **kw)
    _, _, resumed = train_loop(cfg, steps=16, ckpt_dir=d, ckpt_every=10, **kw)
    # steps 11..15 of the resumed run must match the uninterrupted run
    np.testing.assert_allclose(resumed[-3:], full[-3:], rtol=1e-4)


def test_serve_engine_batched_requests():
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=4, max_len=64)
    reqs = [
        Request(rid=i, prompt=np.arange(5 + i, dtype=np.int32) % cfg.vocab_size,
                max_new_tokens=6)
        for i in range(6)
    ]
    results = eng.run(reqs)
    assert len(results) == 6
    for r in results:
        assert r.tokens.shape == (6,)
        assert (r.tokens >= 0).all() and (r.tokens < cfg.vocab_size).all()


def test_greedy_decode_deterministic():
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=2, max_len=64)
    reqs = [Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32), max_new_tokens=8)]
    a = eng.run(list(reqs))[0].tokens
    b = eng.run(list(reqs))[0].tokens
    np.testing.assert_array_equal(a, b)


def _serve_engine(max_len=16, batch_size=4):
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, batch_size=batch_size, max_len=max_len)


def test_serve_overlong_prompt_rejected():
    """A prompt that cannot even be prefilled into the KV cache is refused
    at run() admission instead of silently clobbering the cache tail."""
    eng = _serve_engine(max_len=16)
    good = Request(rid=0, prompt=np.arange(4, dtype=np.int32), max_new_tokens=4)
    bad = Request(rid=1, prompt=np.arange(17, dtype=np.int32) % 7,
                  max_new_tokens=2)
    with pytest.warns(UserWarning, match="rejected"):
        results = eng.run([good, bad])
    assert [r.rid for r in results] == [0]
    assert eng.telemetry["rejected"] == 1
    assert eng.telemetry["requests"] == 1


def test_serve_overbudget_request_truncated():
    """max_new_tokens past the cache is truncated (with a warning) to the
    max_len - len(prompt) + 1 tokens that actually fit."""
    eng = _serve_engine(max_len=16)
    req = Request(rid=7, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=100)
    with pytest.warns(UserWarning, match="truncated to 12"):
        (res,) = eng.run([req])
    assert res.tokens.shape == (12,)  # 16 - 5 + 1
    assert eng.telemetry["truncated"] == 1
    assert eng.telemetry["tokens_generated"] == 12
    # within-budget requests are untouched and raise no warning
    eng2 = _serve_engine(max_len=16)
    (ok,) = eng2.run([Request(rid=8, prompt=np.arange(5, dtype=np.int32),
                              max_new_tokens=6)])
    assert ok.tokens.shape == (6,)
    assert eng2.telemetry["truncated"] == eng2.telemetry["rejected"] == 0


def test_serve_batch_padding_caps_decode_budget():
    """Left-padding packs every slot's cache region at the BATCH prompt
    length, so a short-prompt request sharing a batch with a long prompt is
    capped by the batch's headroom even when its own admission passed."""
    eng = _serve_engine(max_len=16, batch_size=2)
    long_p = Request(rid=0, prompt=np.arange(12, dtype=np.int32) % 7,
                     max_new_tokens=5)
    short_p = Request(rid=1, prompt=np.arange(2, dtype=np.int32),
                      max_new_tokens=8)  # fits alone, not beside long_p
    results = eng.run([long_p, short_p])
    assert results[0].tokens.shape == (5,)
    assert results[1].tokens.shape == (5,)  # capped at 16 - 12 + 1
    assert eng.telemetry["decode_steps"] == 4


def test_serve_decode_stops_when_all_slots_finished():
    """The decode loop runs exactly max(effective budgets) - 1 steps and the
    per-request token telemetry is unchanged by the early stop."""
    eng = _serve_engine(max_len=64, batch_size=4)
    reqs = [Request(rid=0, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new_tokens=6),
            Request(rid=1, prompt=np.asarray([4, 5], np.int32),
                    max_new_tokens=3)]
    results = eng.run(reqs)
    assert [r.tokens.shape for r in results] == [(6,), (3,)]
    assert eng.telemetry["decode_steps"] == 5  # max(6, 3) - 1
    assert eng.telemetry["tokens_generated"] == 9
    assert eng.telemetry["requests"] == 2


ELASTIC_CODE = r"""
import numpy as np, jax
from repro.configs import get_smoke_config
from repro.models.registry import build
from repro.runtime import checkpoint as ckpt
from repro.runtime.elastic import make_mesh_for
from repro.sharding import rules

cfg = get_smoke_config("internlm2-20b")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
import tempfile, os
d = tempfile.mkdtemp()
ckpt.save(d, 0, jax.device_get(params))

# restore on an 8-device (4x2) mesh, then a 4-device (2x2) sub-mesh
m8 = make_mesh_for(jax.devices()[:8], model_parallel=2)
p8 = jax.device_put(ckpt.restore(d, 0, params)[0], rules.named(m8, rules.param_pspecs(params, cfg, m8)))
m4 = make_mesh_for(jax.devices()[:4], model_parallel=2)
p4 = jax.device_put(ckpt.restore(d, 0, params)[0], rules.named(m4, rules.param_pspecs(params, cfg, m4)))

b = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)}
l8 = float(model.loss(p8, b))
l4 = float(model.loss(p4, b))
l1 = float(model.loss(params, b))
assert abs(l8 - l1) < 1e-4 and abs(l4 - l1) < 1e-4, (l1, l4, l8)
print("ELASTIC_OK")
"""


def test_elastic_restore_across_mesh_sizes(multi_device_runner):
    out = multi_device_runner(ELASTIC_CODE, n_devices=8, timeout=600)
    assert "ELASTIC_OK" in out
