"""FPISA query processing (paper Sec. 6): correctness of in-switch pruning and
aggregation against exact baselines."""
import numpy as np
import pytest

from repro.db import query as q




def test_topn_pruning_correct_and_effective():
    RNG = np.random.default_rng(42)
    vals = (RNG.standard_normal(20000) * 100).astype(np.float32)
    pruner = q.TopNPruner(n=10)
    surv = pruner.run(vals)
    exact = q.spark_like_topn(vals, 10)
    # survivors must contain the true top-10 (pruning is lossless for the result)
    got = np.sort(vals[surv])[::-1][:10]
    np.testing.assert_array_equal(got, exact)
    # and the switch must actually prune a large fraction of the stream
    assert pruner.stats.prune_rate > 0.9, pruner.stats


def test_topn_skewed_distribution():
    RNG = np.random.default_rng(1)
    vals = RNG.zipf(1.5, 5000).astype(np.float32)
    pruner = q.TopNPruner(n=5)
    surv = pruner.run(vals)
    np.testing.assert_array_equal(
        np.sort(vals[surv])[::-1][:5], q.spark_like_topn(vals, 5)
    )


def test_groupby_sum_full_fpisa_accuracy():
    RNG = np.random.default_rng(2)
    keys = RNG.integers(0, 32, 5000)
    vals = (RNG.standard_normal(5000) * 10).astype(np.float32)
    agg = q.GroupBySum(num_slots=32, variant="full")
    got = agg.run(keys, vals)
    exact = q.spark_like_groupby(keys, vals)
    for k, v in exact.items():
        # full FPISA: per-add truncation only (paper: queries need full FPISA,
        # not FPISA-A — Sec 6.1); error ~ n_adds * ulp at the running scale
        assert abs(got[k] - v) < 2e-3 * max(1.0, abs(v)), (k, got[k], v)
    assert agg.stats.rows_out == len(exact)  # only aggregates leave the switch


def test_groupby_positive_revenue_like():
    # TPC-H-like: positive prices, narrow range — errors are tiny
    RNG = np.random.default_rng(3)
    keys = RNG.integers(0, 16, 8000)
    vals = (RNG.uniform(1.0, 1000.0, 8000)).astype(np.float32)
    agg = q.GroupBySum(num_slots=16, variant="full")
    got = agg.run(keys, vals)
    exact = q.spark_like_groupby(keys, vals)
    for k, v in exact.items():
        assert abs(got[k] - v) / v < 5e-5


def test_comparison_via_subtraction_sign():
    import jax.numpy as jnp

    from repro.core import fpisa as F

    a = F.encode(jnp.asarray([3.0, -1.0, 0.5], jnp.float32))
    b = F.encode(jnp.asarray([2.0, 1.0, 0.5], jnp.float32))
    gt = q._cmp_planes(a, b)
    np.testing.assert_array_equal(gt, [True, False, False])
