"""Fused single-pass kernel validation (kernels/fpisa_fused.py).

Bit-exactness vs the pure-jnp oracles in kernels/ref.py, swept over shapes
(including R not divisible by TILE_R), block widths B in {128, 256, 512},
formats (fp32/fp16/bf16) and wire dtypes — all in Pallas interpret mode on
CPU (identical semantics to the compiled TPU kernels)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import fpisa, numerics as nx
from repro.kernels import ops, ref
from repro.kernels.fpisa_encode import TILE_R

RNG = np.random.default_rng(7)

# R values straddle the TILE_R=256 grid: 1 row, sub-tile, exact tiles, and
# ragged last tiles (300 = 256 + 44, 513 = 2*256 + 1).
SHAPES = [(1, 256), (8, 128), (256, 256), (300, 256), (513, 128), (64, 512)]
assert any(r % TILE_R for r, _ in SHAPES), "sweep must cover ragged grids"

FMT_DTYPE = {"fp32": jnp.float32, "fp16": jnp.float16, "bf16": jnp.bfloat16}


def _data(r, b, fmt_name="fp32"):
    x = RNG.standard_normal((r, b)).astype(np.float32)
    # spread exponents, but keep within fp16's narrow normal range
    span = 4 if fmt_name == "fp16" else 12
    x = x * np.exp2(RNG.integers(-span, span, (r, b))).astype(np.float32)
    x = jnp.asarray(x, FMT_DTYPE[fmt_name])
    # flush subnormals so packed values are exactly representable planes
    fmt = fpisa.FORMATS[fmt_name]
    tiny = np.float32(2.0 ** (1 - fmt.bias))
    return jnp.where(jnp.abs(x.astype(jnp.float32)) < tiny, 0, x.astype(jnp.float32)).astype(FMT_DTYPE[fmt_name])


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fmt_name", ["fp32", "fp16", "bf16"])
def test_fused_encode_align_matches_oracle(shape, fmt_name):
    x = _data(*shape, fmt_name)
    m_k, b_k = ops.encode_align(x, fmt_name=fmt_name)
    m_r, b_r = ref.fused_encode_align_ref(x, fpisa.FORMATS[fmt_name])
    assert np.array_equal(m_k, m_r)
    assert np.array_equal(b_k, b_r)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("preshift", [0, 2])
def test_fused_equals_two_pass_composition(shape, preshift):
    """fused local-align + residual shift == extract_ref -> align_ref against
    the cross-worker exponent (the bit-exactness claim the backend relies on)."""
    x = _data(*shape)
    exp, man, bmax = ref.extract_ref(x)
    # simulate another worker having raised some block exponents via pmax
    bump = jnp.asarray(RNG.integers(0, 4, bmax.shape), jnp.int32)
    global_bmax = bmax + bump
    direct = ref.align_ref(exp, man, global_bmax, preshift)

    m_local, b_local = ops.encode_align(x)
    composed = nx.arshift(m_local, (global_bmax - b_local)[:, None] + preshift)
    assert np.array_equal(composed, direct)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fmt_name", ["fp32", "fp16", "bf16"])
@pytest.mark.parametrize("wire_dtype", [jnp.int32, jnp.int16])
def test_fused_decode_matches_oracle(shape, fmt_name, wire_dtype):
    fmt = fpisa.FORMATS[fmt_name]
    x = _data(*shape, fmt_name)
    exp, man, bmax = ref.extract_ref(x, fmt)
    preshift = 3  # room so int16 wire holds fp16/bf16 mantissas exactly
    aligned = ref.align_ref(exp, man, bmax, preshift, fmt)
    if wire_dtype != jnp.int32:
        if fmt_name == "fp32":
            pytest.skip("fp32 mantissas do not fit an int16 wire without extra shift")
        aligned = aligned.astype(wire_dtype)
    d_k = ops.decode_fused(aligned, bmax, preshift=preshift, fmt_name=fmt_name)
    d_r = ref.fused_decode_ref(aligned, bmax, preshift, fmt)
    view = np.int32 if fmt_name == "fp32" else np.int16
    assert np.array_equal(np.asarray(d_k).view(view), np.asarray(d_r).view(view))


def test_fused_pipeline_equals_core_block_path():
    """fused encode_align -> residual shift -> decode == the pure-core
    block_encode/block_decode path used by the jnp backend."""
    from repro.core import fpisa as F

    x = _data(64, 256)
    m_local, b_local = ops.encode_align(x)
    man = nx.arshift(m_local, (b_local - b_local)[:, None] + 1)
    out = ops.decode_fused(man, b_local, preshift=1)

    p = F.encode(x)
    be = F.block_max_exponent(p.exp, 256)
    man_ref = F.block_encode(x, be, 256, 1)
    expect = F.block_decode(man_ref, be, 256, 1)
    assert np.array_equal(np.asarray(out).view(np.int32),
                          np.asarray(expect).view(np.int32))


def test_fused_zero_and_special_inputs():
    """All-zero tiles and NaN/Inf clamping flow through the fused path with
    the same semantics as fpisa.encode (specials clamp to max finite)."""
    z = jnp.zeros((8, 256), jnp.float32)
    m, b = ops.encode_align(z)
    assert np.array_equal(m, np.zeros((8, 256), np.int32))
    assert np.array_equal(b, np.zeros((8,), np.int32))
    out = ops.decode_fused(m, b, preshift=0)
    assert np.array_equal(np.asarray(out), np.zeros((8, 256), np.float32))

    x = jnp.full((8, 256), jnp.inf, jnp.float32).at[0, 0].set(jnp.nan)
    m_k, b_k = ops.encode_align(x)
    m_r, b_r = ref.fused_encode_align_ref(x)
    assert np.array_equal(m_k, m_r)
    assert np.array_equal(b_k, b_r)
