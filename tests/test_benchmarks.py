"""Benchmark smoke test (slow): every module in ``benchmarks/run.py`` runs
end-to-end at tiny size (``BENCH_SMOKE=1``) and every machine-readable
``BENCH_*.json`` keeps its schema keys stable — the perf-trajectory tooling
and the CI artifact upload both depend on those keys not drifting.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODULES = [
    "tab1_alu_cost",
    "fig7_gradient_ratio",
    "fig8_error_dist",
    "fig9_convergence",
    "fig10_goodput",
    "fig11_e2e_speedup",
    "fig13_queries",
    "fig_recovery",
    "fig_contention",
    "fig_serve",
    "tab3_resource_util",
    "roofline",
    "fig_autotune",
]

# BENCH_<name>.json -> {top-level results key: [required subkeys]}
SCHEMAS = {
    "fig10": {
        "host_transform": ["switchml_host_transform", "fpisa_host_worstcase",
                           "fpisa_host_zero_copy"],
        "dataplane": ["num_workers", "drop_prob", "legacy_pps", "batched_pps",
                      "speedup", "speedup_target", "speedup_ok",
                      "bit_identical", "batched", "legacy_stats"],
    },
    "fig11": {
        "link_model": ["MobileNetV2", "GoogleNet", "ResNet-50", "VGG19",
                       "LSTM", "BERT", "DeepLight"],
        "bucketing": ["n_leaves", "n_elems", "bucket_bytes", "per_leaf_us",
                      "bucketed_us", "speedup", "bucketed_le_per_leaf",
                      "bit_identical"],
    },
    "fig13": {
        "topn": ["switch_s", "baseline_s", "prune_rate", "rows_to_master",
                 "rows_per_s"],
        "groupby_sum": ["switch_s", "baseline_s", "max_rel_err",
                        "rows_to_master", "rows_per_s"],
        "tpch_q3_like": ["prune_rate"],
        "tpch_q20_like": ["groups_passing_having"],
    },
    "roofline": {
        "kernels": ["jnp", "two_pass", "fused"],
        "fused_ge_two_pass": None,
    },
    "recovery": {
        "switch": ["num_workers", "drop_prob", "nchunks", "clean_s",
                   "faulted_s", "overhead_x", "reclaimed",
                   "clean_goodput_pps", "faulted_goodput_pps", "completed"],
        "training": ["steps", "kill_at", "steps_to_detect", "steps_replayed",
                     "steps_to_recover", "reclaimed", "survivor_mesh",
                     "recovery_overhead_x", "pre_failure_tok_s",
                     "post_failure_tok_s", "bit_identical"],
    },
    "serve": {
        "workload": ["n_requests", "num_slots", "max_len", "page_size",
                     "rate", "prompt_lens", "max_new", "seed"],
        "static": ["goodput_tok_s", "wall_s", "tokens", "decode_steps",
                   "slot_steps", "truncated_by_packing", "ttft_p50",
                   "ttft_p99", "tpot_p50", "tpot_p99"],
        "continuous": ["goodput_tok_s", "wall_s", "tokens", "decode_steps",
                       "slot_steps", "prefills", "queue_peak", "ttft_p50",
                       "ttft_p99", "tpot_p50", "tpot_p99", "kv_pages_peak",
                       "kv_tokens_peak"],
        "comparison": ["goodput_ratio", "goodput_target", "goodput_ok",
                       "kv_pages_peak_tokens", "dense_cache_tokens",
                       "paged_lt_dense", "bit_identical"],
    },
    "autotune": {
        "workload": ["n_layers", "n_leaves", "n_elems"],
        "profile": ["probe_sizes", "n_spans", "trace_path"],
        "model": ["phases", "samples"],
        "search": ["tuned_bucket_bytes", "default_bucket_bytes",
                   "predicted_us"],
        "comparison": ["default_us", "tuned_us", "speedup", "no_worse",
                       "bit_identical"],
    },
    "contention": {
        "config": ["num_jobs", "num_slots", "drop_prob", "priorities",
                   "weights"],
        "jobs": None,
        "fairness": ["jain_normalized", "jain_shared"],
        "query": ["max_rel_err", "num_groups", "rows"],
        "completed": None,
        "rounds": None,
    },
}

PROVENANCE_KEYS = {"bench", "jax_backend", "device_count", "host", "results"}


@pytest.mark.slow
def test_benchmark_suite_smoke(tmp_path):
    env = dict(os.environ)
    env["BENCH_SMOKE"] = "1"
    env["BENCH_DIR"] = str(tmp_path)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO, env.get("PYTHONPATH", "")])
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run"], cwd=REPO,
        capture_output=True, text=True, timeout=3000, env=env)
    assert res.returncode == 0, res.stderr[-4000:]
    assert ",ERROR:" not in res.stdout, res.stdout

    # every module ran to completion
    ok_lines = {line.split(",")[0]: line for line in res.stdout.splitlines()
                if line.endswith(",ok")}
    for name in MODULES:
        assert f"{name}.wall" in ok_lines, (name, res.stdout)

    # every BENCH_*.json landed with a stable schema
    for bench, spec in SCHEMAS.items():
        path = tmp_path / f"BENCH_{bench}.json"
        assert path.exists(), f"{bench} did not write its JSON"
        doc = json.loads(path.read_text())
        assert PROVENANCE_KEYS <= set(doc), (bench, sorted(doc))
        assert doc["bench"] == bench
        results = doc["results"]
        for top, subkeys in spec.items():
            assert top in results, (bench, top, sorted(results))
            if subkeys:
                missing = [k for k in subkeys if k not in results[top]]
                assert not missing, (bench, top, missing)

    # the ISSUE-3 parity bit must hold even at smoke size (timing claims are
    # asserted only at full size — smoke is too noisy for <= comparisons)
    fig11 = json.loads((tmp_path / "BENCH_fig11.json").read_text())
    assert fig11["results"]["bucketing"]["bit_identical"] is True
    fig10 = json.loads((tmp_path / "BENCH_fig10.json").read_text())
    assert fig10["results"]["dataplane"]["bit_identical"] is True
    # the ISSUE-4 recovery invariants hold at smoke size too: the faulted
    # switch run completed with slots actually reclaimed, and the kill-and-
    # resume trajectory matched the uninterrupted run bit for bit
    rec = json.loads((tmp_path / "BENCH_recovery.json").read_text())["results"]
    assert rec["switch"]["completed"] is True
    assert rec["switch"]["reclaimed"] > 0
    assert rec["training"]["bit_identical"] is True
    # the ISSUE-10 autotuner invariants hold at smoke size: the tuned plan
    # is bit-identical to the default and measurably no worse (5% slack)
    at = json.loads((tmp_path / "BENCH_autotune.json").read_text())["results"]
    assert at["comparison"]["bit_identical"] is True
    assert at["comparison"]["no_worse"] is True
    assert at["search"]["tuned_bucket_bytes"] >= 0
    assert rec["training"]["reclaimed"] > 0
    # the ISSUE-6 tenancy invariants hold at smoke size: every tenant of the
    # shared switch completed, and the query stream's group sums carry only
    # FPISA quantization error — contention never corrupts a result
    con = json.loads((tmp_path / "BENCH_contention.json").read_text())["results"]
    assert con["completed"] is True
    assert con["query"]["max_rel_err"] < 1e-3
    assert 0.0 < con["fairness"]["jain_normalized"] <= 1.0
    assert len(con["jobs"]) == 3
    # the ISSUE-7 serving invariants hold at smoke size: the continuous
    # engine's greedy outputs are bit-identical to the per-request static
    # oracle and peak paged KV stays under the dense footprint (the >= 1.3x
    # goodput target is a full-size timing claim — smoke is too noisy)
    srv = json.loads((tmp_path / "BENCH_serve.json").read_text())["results"]
    assert srv["comparison"]["bit_identical"] is True
    assert srv["comparison"]["paged_lt_dense"] is True
    assert srv["continuous"]["kv_pages_peak"] > 0
