"""Fault-tolerant elastic aggregation: worker death across the switch
dataplane and the training runtime (runtime/controller.py, DESIGN.md §8).

Layers:

1. Switch-side — dead-worker slot reclamation: parity of outputs AND stats
   across the three dataplanes (batched jit / legacy per-packet / numpy)
   under injected failures, reclaimed slots are reusable (no pool leak),
   reclamation is idempotent, completed results keep re-serving.
2. Control plane — HealthMonitor revival retracts the shard reassignment,
   the windowed straggler detector ignores one-off GC pauses but flags a
   degraded host, make_mesh_for raises ValueError (not bare assert).
3. Checkpoint — a crash mid-save (torn bundle) is never visible: latest_step
   reports the previous step; params and opt can never land on different
   steps because they commit in one rename.
4. End to end (subprocess, 8 host devices, `-m slow` — the CI fault-injection
   leg): a run with an injected death resumes on the survivor mesh and its
   loss trajectory is BIT-identical to the uninterrupted run, for the
   bucketed fpisa path and the switch_emu protocol-emulation path; revival
   grows the mesh back; the recovery report carries reclaimed > 0.
"""
import json
import os

import numpy as np
import pytest

from repro import switchsim
from repro.core import switch as legacy
from repro.runtime import checkpoint as ckpt
from repro.runtime.controller import FaultEvent, parse_fault_plan
from repro.runtime.elastic import make_mesh_for
from repro.runtime.health import HealthMonitor


# ---------------------------------------------------------------------------
# 1. switch-side slot reclamation
# ---------------------------------------------------------------------------


def _make_switch(kind, w=4, slots=2, elems=32):
    cfg = switchsim.DataplaneConfig(num_workers=w, num_slots=slots,
                                    elems_per_packet=elems)
    if kind == "batched":
        return switchsim.BatchedDataplane(cfg)
    if kind == "numpy":
        return switchsim.NumpyDataplane(cfg)
    return legacy.FpisaSwitch(legacy.SwitchConfig(
        num_workers=w, num_slots=slots, elems_per_packet=elems))


KINDS = ("batched", "numpy", "legacy")


@pytest.mark.parametrize("drop,seed,fail_round,detect", [
    (0.0, 0, 0, 0),   # immediate detection, lossless fabric
    (0.0, 1, 1, 2),   # detection latency: slots park, then unpark
    (0.1, 7, 1, 2),   # lossy fabric on top
    (0.3, 3, 2, 3),   # heavy loss, late detection
])
def test_reclamation_parity_three_dataplanes(drop, seed, fail_round, detect):
    rng = np.random.default_rng(seed)
    w, n = 4, 4 * 96
    vecs = (rng.standard_normal((w, n)) * 0.1).astype(np.float32)
    outs, stats = {}, {}
    for kind in KINDS:
        sw = _make_switch(kind, w=w)
        outs[kind] = switchsim.run_aggregation(
            sw, vecs, drop_prob=drop, seed=seed,
            fail_worker=2, fail_round=fail_round, detect_rounds=detect)
        stats[kind] = {k: sw.stats[k] for k in switchsim.dataplane.COUNTERS}
    for kind in KINDS[1:]:
        assert np.array_equal(outs[KINDS[0]].view(np.int32),
                              outs[kind].view(np.int32)), kind
        assert stats[KINDS[0]] == stats[kind], kind
    # a mid-stream death parks slots that reclamation must free (none stay
    # parked: run_aggregation raises if any chunk never completes); a death
    # before the first packet has nothing in flight to reclaim
    if fail_round > 0:
        assert stats["batched"]["reclaimed"] > 0
    else:
        assert stats["batched"]["reclaimed"] == 0


def test_reclaimed_slots_are_reusable():
    """After a fault + reclamation the same switch must carry further
    aggregations (chunk_base keeps ids monotone) — the pool does not leak."""
    rng = np.random.default_rng(0)
    w, n = 4, 4 * 128
    vecs = (rng.standard_normal((w, n)) * 0.1).astype(np.float32)
    for kind in KINDS:
        sw = _make_switch(kind, w=w)
        switchsim.run_aggregation(sw, vecs, seed=1, fail_worker=1, fail_round=1)
        nchunks = n // 32
        out = switchsim.run_aggregation(sw, vecs, seed=2, chunk_base=nchunks)
        # worker 1 is dead: the follow-up aggregation sums the survivors only
        ref_sw = _make_switch(kind, w=w)
        ref_sw.reclaim_worker(1)
        ref = switchsim.run_aggregation(ref_sw, vecs, seed=2)
        assert np.array_equal(out.view(np.int32), ref.view(np.int32)), kind


def test_reclaim_is_idempotent_and_preserves_completed_results():
    w, elems = 3, 16
    for kind in KINDS:
        sw = _make_switch(kind, w=w, slots=2, elems=elems)
        payload = np.ones((elems,), np.float32)
        ingest = (sw.ingest_batch if kind != "legacy" else
                  lambda ws, cs, ps: ([sw.ingest(legacy.Packet(wk, c, p))
                                       for wk, c, p in zip(ws, cs, ps)]))
        # chunk 0 completes (all 3 workers); chunk 1 stays in flight (w0 only)
        ingest([0, 1, 2, 0], [0, 0, 0, 1],
               np.stack([payload, payload, payload, payload]))
        sw.reclaim_worker(2)
        sw.reclaim_worker(2)  # idempotent: second call must not recount
        stats = sw.stats
        assert stats["reclaimed"] == 1, (kind, stats)
        # the completed chunk's cached (full-worker) result still re-serves
        if kind == "legacy":
            res = sw.ingest(legacy.Packet(1, 0, payload))
            assert res is not None and np.allclose(res.payload, 3.0)
        else:
            ready, results, _ = sw.ingest_batch([1], [0], payload[None])
            assert ready[0] and np.allclose(results[0], 3.0)


def test_dead_worker_packets_dropped_as_stale():
    for kind in KINDS:
        sw = _make_switch(kind, w=2, slots=2, elems=8)
        sw.reclaim_worker(0)
        payload = np.ones((8,), np.float32)
        if kind == "legacy":
            assert sw.ingest(legacy.Packet(0, 0, payload)) is None
        else:
            ready, _, accepted = sw.ingest_batch([0], [0], payload[None])
            assert not ready[0] and not accepted[0]
        assert sw.stats["stale"] == 1 and sw.stats["packets"] == 0, kind


# ---------------------------------------------------------------------------
# 2. health: revival retraction, windowed stragglers, mesh errors
# ---------------------------------------------------------------------------


def _monitor(timeout=10.0, **kw):
    t = [0.0]
    hm = HealthMonitor(hosts=[0, 1, 2, 3], timeout=timeout,
                       clock=lambda: t[0], **kw)
    return hm, t


def test_revival_retracts_reassignment():
    hm, t = _monitor()
    for h in range(4):
        hm.heartbeat(h, 1.0)
    t[0] = 20.0
    for h in (0, 1, 3):
        hm.heartbeat(h, 1.0)
    res = hm.check()
    assert res["dead"] == [2] and hm.reassignments == {2: 0}
    # host 2 comes back: the reassignment MUST be retracted (otherwise two
    # hosts regenerate shard 2 and every global batch duplicates it)
    hm.heartbeat(2, 1.0)
    assert hm.hosts[2].alive
    assert hm.reassignments == {}
    # and check() must not re-reassign the revived host
    res = hm.check()
    assert res["dead"] == [] and res["reassign"] == {}
    assert hm.reassignments == {}


def test_dead_replacement_is_rerouted():
    hm, t = _monitor()
    for h in range(4):
        hm.heartbeat(h, 1.0)
    t[0] = 20.0
    for h in (1, 2, 3):
        hm.heartbeat(h, 1.0)
    assert hm.check()["dead"] == [0]
    assert hm.reassignments == {0: 1}
    t[0] = 40.0
    for h in (2, 3):
        hm.heartbeat(h, 1.0)
    res = hm.check()
    assert res["dead"] == [1]
    # shard 0's replacement (host 1) died: both shards land on survivors
    assert hm.reassignments[0] == 2 and hm.reassignments[1] == 2


def test_gc_pause_does_not_flag_straggler():
    """One slow sample on a healthy host (a GC pause) must NOT flag it: the
    recent-window median absorbs a single spike. The pre-fix detector
    compared the single most-recent step against the global median and
    flagged exactly this case."""
    hm, _ = _monitor(timeout=1e9)
    for _ in range(8):
        for h in range(4):
            hm.heartbeat(h, 1.0)
    hm.heartbeat(0, 9.0)  # one GC pause on host 0
    assert hm.check()["stragglers"] == []


def test_degrading_host_flagged_against_peers():
    """A host whose RECENT window is slow must be flagged even though its own
    long history drags the all-history median up (the pre-fix detector
    compared against all retained samples including the host's own)."""
    hm, _ = _monitor(timeout=1e9)
    for i in range(12):
        for h in range(4):
            # host 3 degrades: fast for 8 steps, then 6x slower
            hm.heartbeat(h, 6.0 if h == 3 and i >= 8 else 1.0)
    assert hm.check()["stragglers"] == [3]


def test_straggler_tiny_sample_guard():
    hm, _ = _monitor(timeout=1e9)
    hm.heartbeat(0, 50.0)  # single sample: not enough evidence
    hm.heartbeat(1, 1.0)
    assert hm.check()["stragglers"] == []


def test_silent_host_window_not_read_as_straggling():
    """A host that stopped heartbeating is on the death track, not the
    straggler track: its frozen window (still holding warmup-slow samples its
    peers aged out) must not be compared against fresh peer windows."""
    hm, t = _monitor(timeout=10.0)
    for i in range(8):
        t[0] = float(i)
        for h in range(4):
            # everyone's first steps are slow (jit warmup), then fast
            hm.heartbeat(h, 8.0 if i < 2 else 1.0)
    # host 0 goes silent; peers age the slow era out of their recent windows
    for i in range(8, 14):
        t[0] = float(i)
        for h in (1, 2, 3):
            hm.heartbeat(h, 1.0)
    res = hm.check()
    assert res["stragglers"] == [] and res["dead"] == []


def test_revival_clears_stale_step_times():
    hm, t = _monitor(timeout=10.0)
    for i in range(6):
        t[0] = float(i)
        for h in range(4):
            hm.heartbeat(h, 5.0 if h == 0 else 1.0)  # host 0 slow, then dies
    t[0] = 30.0
    for h in (1, 2, 3):
        hm.heartbeat(h, 1.0)
    assert hm.check()["dead"] == [0]
    hm.heartbeat(0, 1.0)  # revival drops the pre-outage era
    assert len(hm.hosts[0].step_times) == 1
    for _ in range(4):
        for h in range(4):
            hm.heartbeat(h, 1.0)
    assert hm.check()["stragglers"] == []


def test_make_mesh_for_raises_value_error():
    import jax

    with pytest.raises(ValueError, match="devices"):
        make_mesh_for(jax.devices()[:1], model_parallel=3)


def test_parse_fault_plan():
    plan = parse_fault_plan("kill:2@5, revive:2@9,slow:3@4x6")
    assert plan == (FaultEvent(4, "slow", 3, 6.0), FaultEvent(5, "kill", 2),
                    FaultEvent(9, "revive", 2))
    assert parse_fault_plan("") == () and parse_fault_plan(None) == ()
    with pytest.raises(ValueError):
        parse_fault_plan("explode:1@2")
    with pytest.raises(ValueError):
        parse_fault_plan("kill:1")


# ---------------------------------------------------------------------------
# 3. checkpoint: torn bundles are invisible
# ---------------------------------------------------------------------------


def _bundle_trees():
    import jax.numpy as jnp

    return {"params": {"w": jnp.arange(8.0)}, "opt": {"m": jnp.zeros(8)}}


def test_crash_mid_checkpoint_restores_previous_step(tmp_path):
    d = str(tmp_path)
    trees = _bundle_trees()
    ckpt.save_bundle(d, 1, trees, {"loss": 1.0})
    ckpt.save_bundle(d, 2, trees, {"loss": 0.9})
    # simulate a crash mid-save of step 3: tmp dir only, never renamed
    os.makedirs(os.path.join(d, "step_3.tmp", "params"))
    assert ckpt.latest_step(d) == 2
    # simulate a torn committed step: params landed, opt manifest missing
    # (the failure mode the old split params/_opt layout could produce)
    ckpt.save_bundle(d, 4, trees)
    os.remove(os.path.join(d, "step_4", "opt", "manifest.json"))
    assert ckpt.latest_step(d) == 2
    # ...and one with the opt manifest but a missing leaf file
    ckpt.save_bundle(d, 5, trees)
    victim = next(f for f in os.listdir(os.path.join(d, "step_5", "opt"))
                  if f.endswith(".npy"))
    os.remove(os.path.join(d, "step_5", "opt", victim))
    assert ckpt.latest_step(d) == 2
    restored, extra = ckpt.restore_bundle(d, 2, trees)
    assert extra == {"loss": 0.9}
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(8.0))


def test_train_loop_restores_legacy_split_layout(tmp_path):
    """A ckpt_dir written by the pre-bundle train_loop (params at <dir>, opt
    at <dir>_opt) must still resume instead of crashing on restore_bundle."""
    import jax

    from repro.configs import get_smoke_config
    from repro.launch.train import train_loop
    from repro.models.registry import build
    from repro.optim import optimizers

    cfg = get_smoke_config("qwen1.5-0.5b")
    model = build(cfg)
    params = jax.device_get(model.init(jax.random.PRNGKey(0)))
    opt = jax.device_get(optimizers.init(
        params, optimizers.OptConfig(name=cfg.optimizer, lr=cfg.learning_rate)))
    d = str(tmp_path / "ck")
    ckpt.save(d, 4, params)
    ckpt.save(d + "_opt", 4, opt)
    _, _, hist = train_loop(cfg, steps=6, global_batch=4, seq_len=32,
                            ckpt_dir=d, ckpt_every=50, log_every=100)
    assert len(hist) == 1  # resumed at step 5


def test_controller_resets_preexisting_ckpt_dir(tmp_path):
    """A controller run owns its checkpoint namespace: stale bundles from a
    previous job must not win latest_step or evict fresh ones."""
    from repro.configs import get_smoke_config
    from repro.core.allreduce import AggConfig
    from repro.runtime.controller import ElasticController

    d = str(tmp_path)
    ckpt.save_bundle(d, 40, _bundle_trees())  # stale high-step bundle
    ElasticController(get_smoke_config("qwen1.5-0.5b"), steps=1,
                      global_batch=4, seq_len=16,
                      agg=AggConfig(strategy="fpisa"), num_hosts=1,
                      ckpt_dir=d, log_every=100)
    assert ckpt.committed_steps(d) == []
    # and a fault plan naming a host outside the job is refused up front
    # (a typo'd kill would silently never fire; its revive would KeyError)
    with pytest.raises(ValueError, match="host 5"):
        ElasticController(get_smoke_config("qwen1.5-0.5b"), steps=1,
                          global_batch=4, seq_len=16,
                          agg=AggConfig(strategy="fpisa"), num_hosts=1,
                          ckpt_dir=d, fault_plan="kill:5@0", log_every=100)


def test_bundle_commit_is_all_or_nothing(tmp_path):
    d = str(tmp_path)
    trees = _bundle_trees()
    ckpt.save_bundle(d, 7, trees)
    manifest = json.load(open(os.path.join(d, "step_7", "manifest.json")))
    assert manifest["trees"] == ["opt", "params"]
    # both trees restore from the SAME step by construction
    out, _ = ckpt.restore_bundle(d, 7, trees)
    assert set(out) == {"params", "opt"}
    with pytest.raises(ValueError, match="not a bundle"):
        ckpt.save(d + "/flat", 1, trees["params"])
        ckpt.restore_bundle(d + "/flat", 1, trees)


# ---------------------------------------------------------------------------
# 4. end to end: kill-and-resume == uninterrupted (subprocess, 8 devices)
# ---------------------------------------------------------------------------

RECOVERY_CODE = r"""
import tempfile
import numpy as np
from repro.configs import get_smoke_config
from repro.core.allreduce import AggConfig
from repro.runtime.controller import ElasticController

def run(cfg, agg, fault, steps, **kw):
    return ElasticController(
        cfg, steps=steps, global_batch=8, seq_len=32, agg=agg,
        ckpt_dir=tempfile.mkdtemp(), ckpt_every=3, fault_plan=fault,
        log_every=1000, **kw).run()

# --- bucketed fpisa: kill at 4, 8 -> 4 survivor re-mesh ---
cfg = get_smoke_config("qwen1.5-0.5b")
agg = AggConfig(strategy="fpisa", bucket_bytes=1 << 16)
base = run(cfg, agg, "", 10)
f = run(cfg, agg, "kill:2@4", 10)
assert base["history"] == f["history"], (base["history"], f["history"])
r = f["recoveries"][0]
assert r["reclaimed"] > 0, r
assert r["mesh_hosts"] == [0, 1, 3, 4], r
assert f["switch"]["stale"] == 0  # survivors' resubmissions all landed

# --- kill + revive: mesh shrinks then grows back, still bit-identical ---
f2 = run(cfg, agg, "kill:2@4,revive:2@9", 14)
base2 = run(cfg, agg, "", 14)
assert base2["history"] == f2["history"]
assert f2["mesh_hosts"] == list(range(8)), f2["mesh_hosts"]

# --- switch_emu: the full protocol emulation carries the gradients (tiny
# model: the per-packet numpy dataplane is the reference, not a fast path) ---
tiny = cfg.with_(name="tiny", num_layers=1, d_model=16, num_heads=2,
                 num_kv_heads=2, d_ff=32, vocab_size=64)
agge = AggConfig(strategy="switch_emu")
base3 = run(tiny, agge, "", 8)
f3 = run(tiny, agge, "kill:5@3", 8)
assert base3["history"] == f3["history"], (base3["history"], f3["history"])
assert f3["recoveries"][0]["reclaimed"] > 0
print("RECOVERY_OK")
"""


@pytest.mark.slow
def test_kill_and_resume_bit_identical(multi_device_runner):
    out = multi_device_runner(RECOVERY_CODE, n_devices=8, timeout=900)
    assert "RECOVERY_OK" in out


SHARD_REASSIGN_CODE = r"""
import tempfile
import numpy as np
from repro.configs import get_smoke_config
from repro.core.allreduce import AggConfig
from repro.runtime.controller import ElasticController

cfg = get_smoke_config("qwen1.5-0.5b")
ctl = ElasticController(cfg, steps=8, global_batch=8, seq_len=32,
                        agg=AggConfig(strategy="fpisa"),
                        ckpt_dir=tempfile.mkdtemp(), ckpt_every=3,
                        fault_plan="kill:3@2", log_every=1000)
before = ctl._global_tokens(7).copy()
summary = ctl.run()
# after recovery host 3's shard is owned by its replacement...
assert ctl._shard_owner[3] == ctl.health.reassignments[3] != 3
# ...and the regenerated global batch is bit-identical to pre-failure
np.testing.assert_array_equal(before, ctl._global_tokens(7))
print("REASSIGN_OK")
"""


@pytest.mark.slow
def test_shard_reassignment_invoked_and_stream_identical(multi_device_runner):
    out = multi_device_runner(SHARD_REASSIGN_CODE, n_devices=8, timeout=900)
    assert "REASSIGN_OK" in out
