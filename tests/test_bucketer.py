"""Property-based differential harness for block-aligned gradient bucketing.

Two layers (DESIGN.md §3):

1. Plan invariants — pure-python properties of ``bucketer.make_plan``:
   exact coverage, block-aligned offsets, capacity, reverse-autograd order.
2. Parity — bucketed ``allreduce_tree`` is BIT-identical to the per-leaf path
   across strategy x backend x wire_bits x ragged leaf shapes. Single-worker
   (w=1) runs in-process; the multi-worker flat and hierarchical meshes run
   on 8 host devices in a subprocess (this process keeps 1 device per the
   project brief).

``hypothesis`` is optional (same pattern as tests/test_fpisa.py): without it
the property tests are skipped and a deterministic sweep over hand-picked
ragged trees — non-block-multiple leaves, scalars, a leaf spanning several
buckets, mixed dtypes — covers the same invariants.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import allreduce as AR
from repro.core import bucketer as B
from repro.core.agg import Aggregator

try:  # property tests are a bonus; the deterministic sweep always runs
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# 1. plan invariants
# ---------------------------------------------------------------------------

PLAN_CASES = [
    # (leaf sizes, block, bucket_bytes)
    ([5, 300, 1024, 7, 2600], 256, 4096),
    ([1, 1, 1], 256, 1024),          # scalars only: one block each
    ([100000], 256, 8192),           # single leaf spanning many buckets
    ([0, 64, 0, 65], 64, 512),       # zero-size leaves are passthrough
    ([513], 256, 1024),              # bucket_bytes not hit exactly
    ([17, 33, 65, 129, 255], 32, 256),
]


def _check_plan(sizes, block, bucket_bytes):
    leaves = [jax.ShapeDtypeStruct((n,), jnp.float32) for n in sizes]
    plan = B.make_plan(leaves, block=block, bucket_bytes=bucket_bytes)
    cap = max(block, -(-max(bucket_bytes // 4, 1) // block) * block)

    covered = {i: [] for i in range(len(sizes))}
    for b in plan.buckets:
        assert b.elems <= cap
        assert b.elems % block == 0
        off = 0
        for s in b.segments:
            assert s.offset == off, "segments must tile the bucket contiguously"
            assert s.offset % block == 0, "leaf offsets sit on block boundaries"
            assert s.start % block == 0, "leaves split only at block multiples"
            assert s.span % block == 0
            assert 0 <= s.size <= s.span
            off += s.span
            covered[s.leaf].append((s.start, s.size, s.span))
        assert off == b.elems

    for i, n in enumerate(sizes):
        if n == 0:
            assert i in plan.passthrough
            continue
        padded = -(-n // block) * block
        segs = sorted(covered[i])
        # segments tile [0, padded) exactly: each starts where the previous
        # span ended, and carries every real element in that span
        pos = 0
        for start, size, span in segs:
            assert start == pos, (i, segs)
            assert size == max(0, min(n, start + span) - start), (i, segs)
            pos = start + span
        assert pos == padded, (i, segs)
        assert sum(sz for _, sz, _ in segs) == n, (i, segs)

    # reverse-autograd dispatch: the first bucket starts with the LAST leaf
    nonzero = [i for i, n in enumerate(sizes) if n]
    if nonzero:
        assert plan.buckets[0].segments[0].leaf == nonzero[-1]


@pytest.mark.parametrize("sizes,block,bucket_bytes", PLAN_CASES)
def test_plan_invariants_sweep(sizes, block, bucket_bytes):
    _check_plan(sizes, block, bucket_bytes)


def test_plan_mixed_dtypes_grouped():
    leaves = [
        jax.ShapeDtypeStruct((300,), jnp.float32),
        jax.ShapeDtypeStruct((300,), jnp.bfloat16),
        jax.ShapeDtypeStruct((300,), jnp.float32),
        jax.ShapeDtypeStruct((8,), jnp.int32),  # non-float: passthrough
    ]
    plan = B.make_plan(leaves, block=256, bucket_bytes=1 << 20)
    assert plan.passthrough == (3,)
    for b in plan.buckets:
        dtypes = {jnp.dtype(leaves[s.leaf].dtype).name for s in b.segments}
        assert dtypes == {b.group}, "buckets never mix dtypes"


def test_plan_rejects_bad_args():
    leaves = [jax.ShapeDtypeStruct((8,), jnp.float32)]
    with pytest.raises(ValueError):
        B.make_plan(leaves, block=0, bucket_bytes=1024)
    with pytest.raises(ValueError):
        B.make_plan(leaves, block=256, bucket_bytes=0)


if HAVE_HYPOTHESIS:

    @settings(max_examples=200, deadline=None)
    @given(
        sizes=st.lists(st.integers(0, 5000), min_size=1, max_size=24),
        block=st.sampled_from([32, 64, 256]),
        bucket_kb=st.integers(1, 64),
    )
    def test_plan_invariants_property(sizes, block, bucket_kb):
        _check_plan(sizes, block, bucket_kb * 1024)


# ---------------------------------------------------------------------------
# 2. parity: single worker (w=1), in-process
# ---------------------------------------------------------------------------

RAGGED_TREES = [
    ((37, 13), (5000,), (), (700,), (1300,)),
    ((777,), (1,), (256,), (255,), (257,)),
    ((12000,),),  # one leaf over many buckets
]

COMBOS = [  # (strategy, backend, wire_bits)
    ("native", "jnp", 32),
    ("switchml", "jnp", 32),
    ("fpisa_seq", "jnp", 32),
    ("fpisa", "jnp", 32),
    ("fpisa", "jnp", 16),
    ("fpisa", "jnp", 8),
    ("fpisa", "pallas", 32),
    ("fpisa", "pallas", 16),
    ("fpisa", "pallas", 8),
]


def _tree_from_shapes(shapes, seed=0, scale=0.01):
    rng = np.random.default_rng(seed)
    return {
        f"leaf{i}": jnp.asarray(
            (rng.standard_normal(shape) * scale).astype(np.float32))
        for i, shape in enumerate(shapes)
    }


def _parity_w1(tree, strategy, backend, wire_bits, bucket_bytes, chunk=0):
    mesh = compat.make_mesh((1,), ("data",))

    def make(bb):
        cfg = AR.AggConfig(strategy=strategy, backend=backend,
                           wire_bits=wire_bits, chunk_elems=chunk,
                           bucket_bytes=bb)
        agg = Aggregator(cfg, ("data",))
        return jax.jit(compat.shard_map(
            agg.allreduce_tree, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), tree),),
            out_specs=jax.tree.map(lambda _: P(), tree), check_vma=False))

    a, b = make(0)(tree), make(bucket_bytes)(tree)
    for k in tree:
        av, bv = np.asarray(a[k]), np.asarray(b[k])
        assert av.shape == bv.shape
        assert np.array_equal(av.view(np.int32), bv.view(np.int32)), \
            (strategy, backend, wire_bits, bucket_bytes, k)


@pytest.mark.parametrize("strategy,backend,wire_bits", COMBOS)
def test_parity_single_worker_sweep(strategy, backend, wire_bits):
    for shapes in RAGGED_TREES:
        _parity_w1(_tree_from_shapes(shapes), strategy, backend, wire_bits,
                   bucket_bytes=8192)


def test_parity_single_worker_chunked():
    # chunk_elems % block == 0: the block groupings of the chunked per-leaf
    # and bucketed paths coincide, so bit-identity must survive chunking
    _parity_w1(_tree_from_shapes(RAGGED_TREES[0]), "fpisa", "jnp", 32,
               bucket_bytes=8192, chunk=2048)


if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(
        sizes=st.lists(st.integers(1, 4000), min_size=1, max_size=8),
        combo=st.sampled_from(COMBOS),
        bucket_kb=st.sampled_from([1, 4, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_parity_single_worker_property(sizes, combo, bucket_kb, seed):
        strategy, backend, wire_bits = combo
        tree = _tree_from_shapes([(n,) for n in sizes], seed=seed)
        _parity_w1(tree, strategy, backend, wire_bits,
                   bucket_bytes=bucket_kb * 1024)


# ---------------------------------------------------------------------------
# 3. parity: multi-worker flat + hierarchical meshes (subprocess, 8 devices)
# ---------------------------------------------------------------------------

PARITY_CODE = r"""
import itertools
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import allreduce as AR

rng = np.random.default_rng(0)
mesh_flat = compat.make_mesh((8,), ("data",))
mesh_hier = compat.make_mesh((2, 4), ("pod", "data"))

def mk(shape, scale=0.01, dtype=np.float32):
    return jnp.asarray((rng.standard_normal((8,) + shape) * scale).astype(dtype))

# ragged: non-block-multiple leaves, a scalar, a large-magnitude leaf, a
# bf16 leaf (its own dtype group) and an int32 leaf (passthrough)
tree = {"a": mk((37, 13)), "b": mk((5000,)), "c": mk(()),
        "d": mk((700,), 100.0), "e": mk((1300,)),
        "f": jnp.asarray((rng.standard_normal((8, 400)) * 0.01), jnp.bfloat16),
        "g": jnp.asarray(rng.integers(0, 100, (8, 16)), jnp.int32)}

def run(cfg, hier, t=tree):
    mesh = mesh_hier if hier else mesh_flat
    axes = ("pod", "data") if hier else ("data",)
    spec = jax.tree.map(lambda _: P(axes if hier else "data"), t)
    fn = jax.jit(compat.shard_map(
        lambda s: AR.allreduce_tree(jax.tree.map(lambda x: x[0], s), axes, cfg),
        mesh=mesh, in_specs=(spec,), out_specs=jax.tree.map(lambda _: P(), t),
        check_vma=False))
    return fn(jax.tree.map(lambda x: x.reshape((8, 1) + x.shape[1:]), t))

def assert_equal(a, b, tag, t=tree):
    for k in t:
        av, bv = np.asarray(a[k]), np.asarray(b[k])
        assert av.dtype == bv.dtype and av.shape == bv.shape, (tag, k)
        assert np.array_equal(av.view(np.int32) if av.dtype.itemsize == 4
                              else av.view(np.int16),
                              bv.view(np.int32) if bv.dtype.itemsize == 4
                              else bv.view(np.int16)), (tag, k)

for hier, (strat, backend, wire) in itertools.product((False, True), [
        ("native", "jnp", 32), ("switchml", "jnp", 32),
        ("fpisa_seq", "jnp", 32),
        ("fpisa", "jnp", 32), ("fpisa", "jnp", 16), ("fpisa", "jnp", 8),
        ("fpisa", "pallas", 32), ("fpisa", "pallas", 16),
        ("fpisa", "pallas", 8)]):
    kw = dict(strategy=strat, backend=backend, wire_bits=wire)
    a = run(AR.AggConfig(**kw), hier)
    b = run(AR.AggConfig(bucket_bytes=8192, **kw), hier)
    assert_equal(a, b, (hier, strat, backend, wire))

# narrow cross-pod wire (pod_wire_bits) through the striped hierarchical path
for pw in (16, 8):
    kw = dict(strategy="fpisa", pod_wire_bits=pw)
    assert_equal(run(AR.AggConfig(**kw), True),
                 run(AR.AggConfig(bucket_bytes=8192, **kw), True),
                 ("pod_wire", pw))

# chunked (chunk_elems % block == 0) through the bucketed generic path
kw = dict(strategy="fpisa", chunk_elems=2048)
assert_equal(run(AR.AggConfig(**kw), False),
             run(AR.AggConfig(bucket_bytes=8192, **kw), False), "chunked")

# switch_emu: the host-callback dataplane strategy, tiny tree (it is slow)
small = {"a": tree["a"], "c": tree["c"]}
kw = dict(strategy="switch_emu")
assert_equal(run(AR.AggConfig(**kw), False, small),
             run(AR.AggConfig(bucket_bytes=4096, **kw), False, small),
             "switch_emu", small)
print("BUCKETED_PARITY_OK")
"""


def test_parity_multi_worker(multi_device_runner):
    out = multi_device_runner(PARITY_CODE, n_devices=8, timeout=900)
    assert "BUCKETED_PARITY_OK" in out


TRAIN_BUCKET_CODE = r"""
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
from repro.configs import get_smoke_config
from repro.models.registry import build
from repro.core.allreduce import AggConfig
from repro.optim import optimizers
from repro.sharding import rules
from repro.train.step import make_train_step
from repro.data.pipeline import SyntheticCorpus, ShardedLoader

# fully-manual (pod, data) mesh (see tests/test_backend_parity.py for why)
mesh = compat.make_mesh((2, 4), ("pod", "data"))
cfg = get_smoke_config("internlm2-20b").with_(num_kv_heads=2, num_heads=8)
model = build(cfg)
params0 = model.init(jax.random.PRNGKey(0))
pspecs = rules.param_pspecs(params0, cfg, mesh)
opt_cfg = optimizers.OptConfig(name="adamw", lr=1e-3, warmup_steps=5)
ospecs = rules.opt_pspecs(pspecs, params0, mesh)
GB = 8
loader = ShardedLoader(SyntheticCorpus(cfg.vocab_size), GB, 64)
losses = {}
for bucket_bytes in [0, 1 << 18]:
    params = jax.device_put(params0, rules.named(mesh, pspecs))
    opt = optimizers.init(params, opt_cfg)
    opt = optimizers.OptState(step=jax.device_put(opt.step, NamedSharding(mesh, P())),
                              m=jax.device_put(opt.m, rules.named(mesh, ospecs)),
                              v=jax.device_put(opt.v, rules.named(mesh, ospecs)))
    agg = AggConfig(strategy="fpisa", bucket_bytes=bucket_bytes)
    step = jax.jit(make_train_step(model, mesh, agg, opt_cfg, GB))
    ls = []
    for i in range(3):
        batch = {"tokens": jax.device_put(loader.batch_at(i)["tokens"],
                                          NamedSharding(mesh, P(("pod","data"), None)))}
        params, opt, m = step(params, opt, batch)
        ls.append(float(m["loss"]))
    losses[bucket_bytes] = ls
# the bucketed collective is bit-identical, so the training trajectories
# must agree exactly — not just approximately
assert losses[0] == losses[1 << 18], losses
assert losses[0][-1] < losses[0][0], losses
print("TRAIN_BUCKETED_OK")
"""


def test_train_step_bucketed(multi_device_runner):
    out = multi_device_runner(TRAIN_BUCKET_CODE, n_devices=8, timeout=900)
    assert "TRAIN_BUCKETED_OK" in out
