"""Fixture harness for tools/repro_lint — every rule has at least one
positive (a seeded violation of the historical bug it encodes is flagged
with the right file:line and rule id) and one negative (the idiomatic
clean pattern passes), plus the whole-repo clean gate and the
suppression-comment round trip.

Fixture sources live in strings and are written into tmp trees that
reproduce the repo layout the rule scopes expect (``src/repro/...``); the
linter itself never imports the fixture code, so no jax is needed here.
"""
from __future__ import annotations

import json
import textwrap
from pathlib import Path

import pytest

from tools.repro_lint import (
    available_rules,
    format_findings,
    get_rule,
    main,
    register_rule,
    run_lint,
    unregister_rule,
)

REPO_ROOT = Path(__file__).resolve().parents[1]


def write_tree(root: Path, files: dict) -> None:
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src), encoding="utf-8")


def lint(root: Path, *paths, rules=None):
    return run_lint(list(paths) or ["src"], root=root, rules=rules)


def rules_hit(result):
    return {f.rule for f in result.findings}


def at(result, rule, rel, line):
    """True iff ``rule`` fired at exactly rel:line."""
    return any(f.rule == rule and f.path == rel and f.line == line
               for f in result.findings)


# ---------------------------------------------------------------------------
# exact-scale — PR 3's tiny-normal flush-to-zero via inexact jnp.exp2
# ---------------------------------------------------------------------------


def test_exact_scale_positive(tmp_path):
    write_tree(tmp_path, {"src/repro/core/scale.py": """\
        import jax.numpy as jnp

        def rescale(x, k):
            return x * jnp.exp2(k)

        def rescale2(x, e):
            return x * 2.0 ** e
    """})
    res = lint(tmp_path, "src", rules=["exact-scale"])
    assert at(res, "exact-scale", "src/repro/core/scale.py", 4)
    assert at(res, "exact-scale", "src/repro/core/scale.py", 7)
    assert len(res.findings) == 2


def test_exact_scale_negative_and_scope(tmp_path):
    write_tree(tmp_path, {
        # the idiomatic exact helper: bit-assembled exponent field
        "src/repro/core/scale.py": """\
            import jax.numpy as jnp
            from repro.core import numerics as nx

            def _pow2(e):
                return nx.bitcast_i32_to_f32((jnp.asarray(e, jnp.int32) + 127) << 23)

            def rescale(x, k):
                return (x * _pow2(k // 2)) * _pow2(k - k // 2)
        """,
        # exp2 outside core/kernels (benchmark data gen) is out of scope
        "benchmarks/gen.py": """\
            import numpy as np
            x = np.exp2(np.arange(4))
        """,
    })
    res = lint(tmp_path, "src", "benchmarks", rules=["exact-scale"])
    assert res.findings == []


# ---------------------------------------------------------------------------
# bit-identity — PR 4's jnp.sum over the (W,) per-worker loss vector
# ---------------------------------------------------------------------------


def test_bit_identity_positive_worker_axis_sum(tmp_path):
    write_tree(tmp_path, {"src/repro/train/step.py": """\
        import jax.numpy as jnp

        def finish(losses, w):
            return jnp.sum(losses) / w
    """})
    res = lint(tmp_path, "src", rules=["bit-identity"])
    assert at(res, "bit-identity", "src/repro/train/step.py", 4)


def test_bit_identity_positive_raw_psum(tmp_path):
    write_tree(tmp_path, {"src/repro/serve/agg.py": """\
        from jax import lax

        def reduce_stats(x, axes):
            return lax.psum(x, axes)
    """})
    res = lint(tmp_path, "src", rules=["bit-identity"])
    assert at(res, "bit-identity", "src/repro/serve/agg.py", 4)


def test_bit_identity_negative(tmp_path):
    write_tree(tmp_path, {
        # fixed-order scan (the fix shipped in PR 4) is clean
        "src/repro/train/step.py": """\
            import jax
            import jax.numpy as jnp

            def finish(losses, w):
                total, _ = jax.lax.scan(
                    lambda c, v: (c + v, None), jnp.float32(0), losses)
                return total / w
        """,
        # the implementation site may use raw collectives
        "src/repro/core/allreduce.py": """\
            from jax import lax

            def native_allreduce(x, axes, cfg):
                return lax.psum(x, tuple(axes))
        """,
    })
    res = lint(tmp_path, "src", rules=["bit-identity"])
    assert res.findings == []


# ---------------------------------------------------------------------------
# jax-in-callback — PR 2's CPU PJRT deadlock
# ---------------------------------------------------------------------------


def test_jax_in_callback_positive_transitive(tmp_path):
    write_tree(tmp_path, {"src/repro/core/cb.py": """\
        import jax
        import jax.numpy as jnp
        import numpy as np

        def helper(v):
            return jnp.sum(v)

        def run(x):
            def host(vals):
                return np.asarray(helper(vals))
            return jax.pure_callback(host, x, x)
    """})
    res = lint(tmp_path, "src", rules=["jax-in-callback"])
    # flagged at the jnp reference inside the transitively-reached helper
    assert at(res, "jax-in-callback", "src/repro/core/cb.py", 6)


def test_jax_in_callback_negative_numpy_only(tmp_path):
    write_tree(tmp_path, {"src/repro/core/cb.py": """\
        import jax
        import numpy as np

        def run(x):
            def host(vals):
                return np.asarray(vals).sum(axis=0)
            return jax.pure_callback(host, x, x)
    """})
    res = lint(tmp_path, "src", rules=["jax-in-callback"])
    assert res.findings == []


# ---------------------------------------------------------------------------
# donation-safety — the serve/scheduler.py donated-KV-pool pattern
# ---------------------------------------------------------------------------


def test_donation_safety_positive_read_after_donate(tmp_path):
    write_tree(tmp_path, {"src/repro/serve/sched.py": """\
        from functools import partial
        import jax

        @partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
        def step(fn, pool, toks):
            return fn(pool, toks)

        def drive(fn, pool, toks):
            out = step(fn, pool, toks)
            return pool.sum() + out
    """})
    res = lint(tmp_path, "src", rules=["donation-safety"])
    assert at(res, "donation-safety", "src/repro/serve/sched.py", 10)


def test_donation_safety_positive_loop_wraparound(tmp_path):
    # the next iteration re-reads the donated buffer even though the read
    # is textually ABOVE the call
    write_tree(tmp_path, {"src/repro/serve/sched.py": """\
        from functools import partial
        import jax

        @partial(jax.jit, donate_argnums=(0,))
        def step(pool):
            return pool

        def drive(pool, n):
            for _ in range(n):
                x = pool * 2
                out = step(pool)
            return out
    """})
    res = lint(tmp_path, "src", rules=["donation-safety"])
    assert at(res, "donation-safety", "src/repro/serve/sched.py", 10)


def test_donation_safety_negative_rebind(tmp_path):
    write_tree(tmp_path, {"src/repro/serve/sched.py": """\
        from functools import partial
        import jax

        @partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
        def step(fn, pool, toks):
            return fn(pool, toks)

        def drive(fn, pool, toks):
            nxt, pool = step(fn, pool, toks)
            return pool, nxt
    """})
    res = lint(tmp_path, "src", rules=["donation-safety"])
    assert res.findings == []


# ---------------------------------------------------------------------------
# facade-only — PR 5's contract, statically
# ---------------------------------------------------------------------------


def test_facade_only_positive(tmp_path):
    write_tree(tmp_path, {"examples/run.py": """\
        from repro.core import allreduce as AR
        from repro.core.allreduce import stacked_allreduce

        def agg(x, cfg):
            return AR.allreduce(x, ("data",), cfg)

        def pick(name):
            return STRATEGIES[name]
    """})
    res = lint(tmp_path, "examples", rules=["facade-only"])
    assert at(res, "facade-only", "examples/run.py", 2)  # shim import
    assert at(res, "facade-only", "examples/run.py", 5)  # shim call
    assert at(res, "facade-only", "examples/run.py", 8)  # STRATEGIES[...]


def test_facade_only_negative_facade_and_config(tmp_path):
    write_tree(tmp_path, {"examples/run.py": """\
        from repro.core.agg import AggConfig, Aggregator
        from repro.core.allreduce import AggConfig as LegacyCfgImport

        def agg(x):
            a = Aggregator(AggConfig(strategy="fpisa"), ("data",))
            return a.allreduce(x), a.allreduce_tree({"g": x})
    """})
    res = lint(tmp_path, "examples", rules=["facade-only"])
    assert res.findings == []


# ---------------------------------------------------------------------------
# rng-discipline — the loadgen/benchmark reproducibility contract
# ---------------------------------------------------------------------------


def test_rng_discipline_positive(tmp_path):
    write_tree(tmp_path, {"benchmarks/gen.py": """\
        import numpy as np
        from numpy.random import rand

        np.random.seed(0)
        x = np.random.normal(size=8)
        y = rand(3)
    """})
    res = lint(tmp_path, "benchmarks", rules=["rng-discipline"])
    assert at(res, "rng-discipline", "benchmarks/gen.py", 4)
    assert at(res, "rng-discipline", "benchmarks/gen.py", 5)
    assert at(res, "rng-discipline", "benchmarks/gen.py", 6)


def test_rng_discipline_negative_generator(tmp_path):
    write_tree(tmp_path, {"benchmarks/gen.py": """\
        import numpy as np

        rng = np.random.default_rng(np.random.SeedSequence([1, 2]))
        x = rng.normal(size=8)
    """})
    res = lint(tmp_path, "benchmarks", rules=["rng-discipline"])
    assert res.findings == []


# ---------------------------------------------------------------------------
# timing-discipline — perf_counter + context-manager spans on phase paths
# ---------------------------------------------------------------------------


def test_timing_discipline_positive(tmp_path):
    write_tree(tmp_path, {"benchmarks/bad_bench.py": """\
        import time
        from time import time as now
        from repro import trace

        def run(fn):
            t0 = time.time()
            fn()
            dt = now() - t0
            sp = trace.span("bench.step")
            sp.start()
            fn()
            sp.end()
            trace.span("chained").start()
            return dt
    """})
    res = lint(tmp_path, "benchmarks", rules=["timing-discipline"])
    assert at(res, "timing-discipline", "benchmarks/bad_bench.py", 6)
    assert at(res, "timing-discipline", "benchmarks/bad_bench.py", 8)
    assert at(res, "timing-discipline", "benchmarks/bad_bench.py", 10)
    assert at(res, "timing-discipline", "benchmarks/bad_bench.py", 13)
    assert len(res.findings) == 4


def test_timing_discipline_negative_clean_and_scope(tmp_path):
    write_tree(tmp_path, {
        # idiomatic: perf_counter + context-manager spans; thread.start() and
        # span-as-context-manager must not fire
        "src/repro/serve/sched.py": """\
            import threading
            from time import perf_counter

            from repro import trace

            def step(fn):
                t0 = perf_counter()
                with trace.span("serve.decode", phase="decode") as sp:
                    out = fn()
                    sp.sync(out)
                t = threading.Thread(target=fn)
                t.start()
                return perf_counter() - t0
        """,
        # out of scope: wall-clock in a data pipeline is not a phase path
        "src/repro/data/loader.py": """\
            import time

            def stamp():
                return time.time()
        """,
    })
    res = lint(tmp_path, "src", rules=["timing-discipline"])
    assert res.findings == []


# ---------------------------------------------------------------------------
# mirror-parity — the three-way dataplane / numpy-mirror contract
# ---------------------------------------------------------------------------

CLEAN_MIRROR = {
    "src/repro/switchsim/__init__.py": """\
        COUNTERS = ("packets", "duplicates")
        SLOT_STATE_FIELDS = ("exp", "man")
    """,
    "src/repro/switchsim/dataplane.py": """\
        from repro.switchsim import COUNTERS, SLOT_STATE_FIELDS

        class DataplaneState:
            exp: int
            man: int

        _I_PACKETS, _I_DUP = range(len(COUNTERS))

        class NumpyDataplane:
            def __init__(self, cfg):
                self._exp = 0
                self._man = 0
    """,
    "src/repro/switchsim/npfpisa.py": """\
        EXP_BITS, MAN_BITS, BIAS = 8, 23, 127

        def encode(x): pass
        def renormalize(e, m): pass
        def fpisa_a_add(ae, am, ie, im): pass
        def fpisa_add_full(ae, am, ie, im): pass
    """,
    "src/repro/core/fpisa.py": """\
        def encode(x, fmt=None): pass
        def renormalize(p, fmt=None): pass
        def fpisa_a_add(acc, inp, fmt=None): pass
        def fpisa_add_full(acc, inp, fmt=None): pass
    """,
    "src/repro/core/numerics.py": """\
        FP32 = FpFormat("fp32", exp_bits=8, man_bits=23)
    """,
    "src/repro/kernels/ref.py": """\
        def fused_encode_align_ref(x): pass
    """,
    "src/repro/kernels/fpisa_fused.py": """\
        def fused_encode_align(x): pass
    """,
}


def test_mirror_parity_negative_clean_tree(tmp_path):
    write_tree(tmp_path, CLEAN_MIRROR)
    res = lint(tmp_path, "src", rules=["mirror-parity"])
    assert res.findings == []


def _mirror_with(tmp_path, rel, src):
    files = dict(CLEAN_MIRROR)
    files[rel] = src
    write_tree(tmp_path, files)
    return lint(tmp_path, "src", rules=["mirror-parity"])


def test_mirror_parity_counter_drift(tmp_path):
    # a counter added to the jitted dataplane only: the _I_* unpack grows
    # but the shared COUNTERS (and so the numpy mirror's stats) does not
    res = _mirror_with(tmp_path, "src/repro/switchsim/dataplane.py", """\
        from repro.switchsim import COUNTERS, SLOT_STATE_FIELDS

        class DataplaneState:
            exp: int
            man: int

        _I_PACKETS, _I_DUP, _I_NEW = range(3)

        class NumpyDataplane:
            def __init__(self, cfg):
                self._exp = 0
                self._man = 0
    """)
    assert at(res, "mirror-parity", "src/repro/switchsim/dataplane.py", 7)


def test_mirror_parity_duplicated_literal(tmp_path):
    res = _mirror_with(tmp_path, "src/repro/switchsim/dataplane.py", """\
        COUNTERS = ("packets", "duplicates")

        class DataplaneState:
            exp: int
            man: int

        _I_PACKETS, _I_DUP = range(len(COUNTERS))

        class NumpyDataplane:
            def __init__(self, cfg):
                self._exp = 0
                self._man = 0
    """)
    assert at(res, "mirror-parity", "src/repro/switchsim/dataplane.py", 1)


def test_mirror_parity_state_field_drift(tmp_path):
    res = _mirror_with(tmp_path, "src/repro/switchsim/dataplane.py", """\
        from repro.switchsim import COUNTERS, SLOT_STATE_FIELDS

        class DataplaneState:
            exp: int
            man: int
            extra_plane: int

        _I_PACKETS, _I_DUP = range(len(COUNTERS))

        class NumpyDataplane:
            def __init__(self, cfg):
                self._exp = 0
                self._man = 0
    """)
    assert at(res, "mirror-parity", "src/repro/switchsim/dataplane.py", 3)


def test_mirror_parity_numpy_mirror_missing_field(tmp_path):
    res = _mirror_with(tmp_path, "src/repro/switchsim/dataplane.py", """\
        from repro.switchsim import COUNTERS, SLOT_STATE_FIELDS

        class DataplaneState:
            exp: int
            man: int

        _I_PACKETS, _I_DUP = range(len(COUNTERS))

        class NumpyDataplane:
            def __init__(self, cfg):
                self._exp = 0
    """)
    # anchored at the numpy mirror's __init__ def line
    assert at(res, "mirror-parity", "src/repro/switchsim/dataplane.py", 10)


def test_mirror_parity_missing_mirror_function(tmp_path):
    res = _mirror_with(tmp_path, "src/repro/switchsim/npfpisa.py", """\
        EXP_BITS, MAN_BITS, BIAS = 8, 23, 127

        def encode(x): pass
        def renormalize(e, m): pass
        def fpisa_add_full(ae, am, ie, im): pass
    """)
    assert any(f.rule == "mirror-parity" and "fpisa_a_add" in f.message
               for f in res.findings)


def test_mirror_parity_wire_constant_drift(tmp_path):
    res = _mirror_with(tmp_path, "src/repro/switchsim/npfpisa.py", """\
        EXP_BITS, MAN_BITS, BIAS = 8, 23, 126

        def encode(x): pass
        def renormalize(e, m): pass
        def fpisa_a_add(ae, am, ie, im): pass
        def fpisa_add_full(ae, am, ie, im): pass
    """)
    assert any(f.rule == "mirror-parity" and "BIAS" in f.message
               for f in res.findings)


def test_mirror_parity_kernel_oracle_drift(tmp_path):
    res = _mirror_with(tmp_path, "src/repro/kernels/ref.py", """\
        def fused_encode_align_ref(x): pass
        def fused_decode_ref(m, b): pass
    """)
    assert any(f.rule == "mirror-parity" and "fused_decode" in f.message
               for f in res.findings)


# ---------------------------------------------------------------------------
# suppressions round-trip
# ---------------------------------------------------------------------------

_VIOLATION = """\
    import numpy as np
    x = np.random.normal(size=4){comment}
"""


def test_suppression_round_trip(tmp_path):
    rel = "benchmarks/gen.py"
    # unsuppressed: flagged
    write_tree(tmp_path, {rel: _VIOLATION.format(comment="")})
    res = lint(tmp_path, "benchmarks", rules=["rng-discipline"])
    assert rules_hit(res) == {"rng-discipline"} and not res.suppressed

    # same-line suppression: moved to the suppressed list, run is clean
    write_tree(tmp_path, {rel: _VIOLATION.format(
        comment="  # repro-lint: disable=rng-discipline  fixture noise")})
    res = lint(tmp_path, "benchmarks", rules=["rng-discipline"])
    assert res.clean and [f.rule for f in res.suppressed] == ["rng-discipline"]

    # comment-only line above the violation also suppresses it
    write_tree(tmp_path, {rel: """\
        import numpy as np
        # repro-lint: disable=rng-discipline
        x = np.random.normal(size=4)
    """})
    res = lint(tmp_path, "benchmarks", rules=["rng-discipline"])
    assert res.clean and len(res.suppressed) == 1

    # file-level disable
    write_tree(tmp_path, {rel: """\
        # repro-lint: disable-file=rng-discipline
        import numpy as np
        x = np.random.normal(size=4)
        y = np.random.rand(2)
    """})
    res = lint(tmp_path, "benchmarks", rules=["rng-discipline"])
    assert res.clean and len(res.suppressed) == 2

    # a directive inside a string literal must NOT suppress anything
    write_tree(tmp_path, {rel: """\
        import numpy as np
        s = "# repro-lint: disable-file=rng-discipline"
        x = np.random.normal(size=4)
    """})
    res = lint(tmp_path, "benchmarks", rules=["rng-discipline"])
    assert not res.clean


# ---------------------------------------------------------------------------
# registry + CLI + whole-repo gate
# ---------------------------------------------------------------------------


def test_registry_round_trip_and_duplicate_guard():
    @register_rule("test-fixture-rule", description="fixture")
    def _rule(mod, project):
        return ()

    try:
        assert "test-fixture-rule" in available_rules()
        with pytest.raises(ValueError, match="already registered"):
            register_rule("test-fixture-rule")(lambda m, p: ())
        register_rule("test-fixture-rule", overwrite=True)(lambda m, p: ())
    finally:
        unregister_rule("test-fixture-rule")
    assert "test-fixture-rule" not in available_rules()


def test_unknown_rule_nearest_match():
    with pytest.raises(ValueError, match="did you mean 'facade-only'"):
        get_rule("facade_only")


def test_cli_json_output_and_exit_codes(tmp_path, capsys):
    write_tree(tmp_path, {"src/repro/core/scale.py": """\
        import jax.numpy as jnp
        def f(x, k):
            return x * jnp.exp2(k)
    """})
    code = main(["--root", str(tmp_path), "src", "--format", "json",
                 "--output", str(tmp_path / "report.json")])
    assert code == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["clean"] is False
    assert payload["findings"][0]["rule"] == "exact-scale"
    assert payload["findings"][0]["path"] == "src/repro/core/scale.py"
    assert json.loads((tmp_path / "report.json").read_text()) == payload

    # fixing the file flips the exit code to 0
    write_tree(tmp_path, {"src/repro/core/scale.py": "x = 1\n"})
    assert main(["--root", str(tmp_path), "src", "--format", "json"]) == 0
    capsys.readouterr()

    # unknown rule name is a usage error (2), with the nearest match
    assert main(["--root", str(tmp_path), "src", "--rules", "exact_scale"]) == 2


def test_whole_repo_lints_clean():
    """The standing gate: the shipped tree has no unsuppressed findings
    under ALL rules (mirrors the CI `lint` job and tests/run.sh)."""
    res = run_lint(["src", "tests", "benchmarks", "examples"],
                   root=REPO_ROOT)
    assert res.errors == []
    assert res.findings == [], format_findings(res)


def test_human_format_lists_file_line_rule(tmp_path):
    write_tree(tmp_path, {"src/repro/core/scale.py": """\
        import jax.numpy as jnp
        y = jnp.exp2(3)
    """})
    res = lint(tmp_path, "src", rules=["exact-scale"])
    text = format_findings(res)
    assert "src/repro/core/scale.py:2:4: exact-scale:" in text
    assert "FAIL: 1 finding(s)" in text
