"""Aggregator facade + strategy registry (repro.core.agg).

Covers the registry round-trip (register -> construct -> dispatch ->
unregister), construction-time capability validation, the named-options /
nearest-match error surface, the shared CLI pair (add_agg_args /
AggConfig.from_args), deprecation-shim behavior, and a parity sweep pinning
``Aggregator`` bit-identical to the legacy module-level functions for every
strategy x backend x stacked x bucketed combination (in-process at W=1; the
8-device mesh sweep runs in a subprocess per the project brief).
"""
import argparse
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import agg as AG
from repro.core import allreduce as AR
from repro.core.agg import (
    AggConfig, Aggregator, add_agg_args, available_strategies, get_strategy,
    register_strategy, resolve_backend, unregister_strategy,
)

STRATS = ("native", "switchml", "fpisa", "fpisa_seq", "switch_emu")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_builtins_registered():
    assert set(STRATS) <= set(available_strategies())
    spec = get_strategy("fpisa")
    assert spec.supports_stacking and spec.supports_hierarchical
    assert spec.flat_phases and spec.hier_phases and spec.stacked_phases
    assert not spec.requires_host_callback
    assert get_strategy("switch_emu").requires_host_callback
    assert get_strategy("native").chunk_noop


def test_registry_roundtrip_register_construct_dispatch():
    """A new strategy registered declaratively is immediately dispatchable
    through the facade — the NetFC-style plug-in path."""

    @register_strategy("_test_double", description="2x psum (test only)")
    def double_allreduce(x, axes, cfg):
        return lax.psum(x, axes) * 2.0

    try:
        assert "_test_double" in available_strategies()
        agg = Aggregator(AggConfig(strategy="_test_double"), ("data",))
        mesh = compat.make_mesh((1,), ("data",))
        x = jnp.arange(8, dtype=jnp.float32)
        out = jax.jit(compat.shard_map(agg.allreduce, mesh=mesh,
                                       in_specs=P(), out_specs=P(),
                                       check_vma=False))(x)
        np.testing.assert_array_equal(np.asarray(out), np.arange(8) * 2.0)
        # ... and allreduce_tree reaches the same registered fn per leaf
        tree_out = jax.jit(compat.shard_map(
            agg.allreduce_tree, mesh=mesh, in_specs=({"a": P()},),
            out_specs={"a": P()}, check_vma=False))({"a": x})
        np.testing.assert_array_equal(np.asarray(tree_out["a"]),
                                      np.arange(8) * 2.0)
    finally:
        unregister_strategy("_test_double")
    assert "_test_double" not in available_strategies()


def test_duplicate_registration_refused():
    def fn(x, axes, cfg):
        return x

    register_strategy("_test_dup")(fn)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_strategy("_test_dup")(fn)
        register_strategy("_test_dup", overwrite=True)(fn)  # explicit wins
    finally:
        unregister_strategy("_test_dup")


# ---------------------------------------------------------------------------
# error surface: named options + nearest match
# ---------------------------------------------------------------------------


def test_unknown_strategy_names_options_and_nearest():
    with pytest.raises(ValueError) as ei:
        get_strategy("fpsia")
    msg = str(ei.value)
    for s in STRATS:
        assert s in msg
    assert "did you mean 'fpisa'" in msg
    # the same error surfaces from Aggregator construction
    with pytest.raises(ValueError, match="did you mean 'fpisa'"):
        Aggregator(AggConfig(strategy="fpsia"), ("data",))


def test_unknown_backend_names_options_and_nearest():
    with pytest.raises(ValueError) as ei:
        resolve_backend("palas")
    msg = str(ei.value)
    assert "auto" in msg and "jnp" in msg and "pallas" in msg
    assert "did you mean 'pallas'" in msg
    with pytest.raises(ValueError, match="did you mean 'pallas'"):
        AggConfig(backend="palas")


def test_auto_backend_resolves_by_platform():
    """auto must pick the measured-fastest backend per platform. On CPU the
    interpreted Pallas path LOSES to jnp (BENCH_roofline: fused Pallas 4.1 ms
    vs jnp 1.9 ms for the 16M-elem transform), so auto -> jnp there — the
    regression this test pins (auto used to be read as "pallas everywhere")."""
    assert AG._AUTO_BACKEND == {"tpu": "pallas", "gpu": "jnp", "cpu": "jnp"}
    want = AG._AUTO_BACKEND.get(jax.default_backend(), "jnp")
    assert resolve_backend("auto") == want
    # the facade resolves at construction, not per call
    assert Aggregator(AggConfig(), ("data",)).backend == want
    if jax.default_backend() == "cpu":  # CI always lands here
        assert resolve_backend("auto") == "jnp"
    # explicit names always pass through untouched
    assert resolve_backend("pallas") == "pallas"
    assert resolve_backend("jnp") == "jnp"


# ---------------------------------------------------------------------------
# capability validation at construction (not deep in dispatch)
# ---------------------------------------------------------------------------


def test_stacked_plus_chunk_refused_at_construction():
    with pytest.raises(ValueError, match="stacked"):
        Aggregator(AggConfig(chunk_elems=512), ("data",), stacked=True)


def test_switch_emu_fmt_validated_at_construction():
    with pytest.raises(ValueError, match="fp32-only"):
        Aggregator(AggConfig(strategy="switch_emu", fmt_name="bf16"), ("data",))
    Aggregator(AggConfig(strategy="switch_emu"), ("data",))  # fp32 fine


def test_unsupported_capabilities_refused_at_construction():
    register_strategy("_test_rigid", supports_chunking=False,
                      description="no chunking, no stacking")(
        lambda x, axes, cfg: lax.psum(x, axes))
    try:
        with pytest.raises(ValueError, match="chunk_elems"):
            Aggregator(AggConfig(strategy="_test_rigid", chunk_elems=256),
                       ("data",))
        with pytest.raises(ValueError, match="stacked"):
            Aggregator(AggConfig(strategy="_test_rigid"), ("data",),
                       stacked=True)
        Aggregator(AggConfig(strategy="_test_rigid"), ("data",))  # plain ok
    finally:
        unregister_strategy("_test_rigid")


def test_bucketed_chunk_alignment_validated():
    ok = AggConfig(strategy="fpisa", bucket_bytes=8192, chunk_elems=2048)
    Aggregator(ok, ("data",))
    bad = AggConfig(strategy="fpisa", bucket_bytes=8192, chunk_elems=1000)
    with pytest.raises(ValueError, match="multiple of block"):
        Aggregator(bad, ("data",))


# ---------------------------------------------------------------------------
# shared CLI pair
# ---------------------------------------------------------------------------


def test_add_agg_args_from_args_roundtrip():
    ap = argparse.ArgumentParser()
    add_agg_args(ap)
    ns = ap.parse_args([
        "--agg-strategy", "switchml", "--agg-backend", "jnp",
        "--agg-chunk", "512", "--bucket-bytes", "4096",
        "--agg-wire-bits", "16", "--agg-fmt", "fp32"])
    cfg = AggConfig.from_args(ns)
    assert cfg == AggConfig(strategy="switchml", backend="jnp",
                            chunk_elems=512, bucket_bytes=4096, wire_bits=16)


def test_add_agg_args_legacy_aliases():
    ap = argparse.ArgumentParser()
    add_agg_args(ap)
    ns = ap.parse_args(["--agg", "native", "--wire-bits", "16",
                        "--pod-wire-bits", "8"])
    cfg = AggConfig.from_args(ns)
    assert (cfg.strategy, cfg.wire_bits, cfg.pod_wire_bits) == ("native", 16, 8)


def test_from_args_validates_with_nearest_match():
    ap = argparse.ArgumentParser()
    add_agg_args(ap)
    with pytest.raises(ValueError, match="did you mean 'switchml'"):
        AggConfig.from_args(ap.parse_args(["--agg-strategy", "swichml"]))
    with pytest.raises(ValueError, match="did you mean 'jnp'"):
        AggConfig.from_args(ap.parse_args(["--agg-backend", "jnpp"]))


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------


def test_legacy_module_functions_warn_and_delegate():
    mesh = compat.make_mesh((1,), ("data",))
    cfg = AggConfig(strategy="fpisa")
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal(1024).astype(np.float32))
    agg = Aggregator(cfg, ("data",))
    want = jax.jit(compat.shard_map(agg.allreduce, mesh=mesh, in_specs=P(),
                                    out_specs=P(), check_vma=False))(x)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = jax.jit(compat.shard_map(
            # repro-lint: disable=facade-only  this test exercises the shim
            lambda v: AR.allreduce(v, ("data",), cfg), mesh=mesh,
            in_specs=P(), out_specs=P(), check_vma=False))(x)
        assert any(issubclass(i.category, DeprecationWarning) for i in w), \
            "legacy allreduce() must raise DeprecationWarning"
    assert np.array_equal(np.asarray(want).view(np.int32),
                          np.asarray(got).view(np.int32))


def test_facade_path_raises_no_deprecation_from_repro():
    """The in-tree (facade + bucketer) path must be shim-free: any
    DeprecationWarning attributed to a repro.* module is a bug (and the
    pytest.ini filter turns it into an error suite-wide)."""
    mesh = compat.make_mesh((1,), ("data",))
    tree = {"a": jnp.ones((700,), jnp.float32),
            "b": jnp.ones((64,), jnp.float32)}
    agg = Aggregator(AggConfig(strategy="fpisa", bucket_bytes=4096), ("data",))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        jax.jit(compat.shard_map(
            agg.allreduce_tree, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), tree),),
            out_specs=jax.tree.map(lambda _: P(), tree),
            check_vma=False))(tree)
    dep = [i for i in w if issubclass(i.category, DeprecationWarning)
           and "repro.core.allreduce" in str(i.message)]
    assert not dep, [str(i.message) for i in dep]


# ---------------------------------------------------------------------------
# parity: facade == legacy module-level functions, bit for bit
# ---------------------------------------------------------------------------


def _ragged_tree(rng):
    return {f"l{i}": jnp.asarray(
        (rng.standard_normal(n) * 0.01).astype(np.float32))
        for i, n in enumerate((1500, 256, 77, 513))}


@pytest.mark.parametrize("strategy", STRATS)
@pytest.mark.parametrize("stacked", [False, True])
@pytest.mark.parametrize("bucket_bytes", [0, 4096])
def test_parity_facade_vs_legacy_w1(strategy, stacked, bucket_bytes):
    """Aggregator results must equal the legacy module-level functions bit
    for bit — every strategy x stacked x bucketed (W=1 in-process; the
    multi-device sweep is the subprocess test below)."""
    mesh = compat.make_mesh((1,), ("data",))
    rng = np.random.default_rng(7)
    tree = _ragged_tree(rng)
    if stacked:  # leading logical-worker axis of k=2
        tree = jax.tree_util.tree_map(
            lambda v: jnp.stack([v, v * 0.5 + 0.001]), tree)
    cfg = AggConfig(strategy=strategy, backend="jnp",
                    bucket_bytes=bucket_bytes)
    agg = Aggregator(cfg, ("data",), stacked=stacked)
    legacy = AR.stacked_allreduce_tree if stacked else AR.allreduce_tree

    def shmap(fn):
        # out_specs only needs the pytree STRUCTURE (stacked outputs drop the
        # leading worker axis but keep the same treedef)
        return jax.jit(compat.shard_map(
            fn, mesh=mesh, in_specs=(jax.tree.map(lambda _: P(), tree),),
            out_specs=jax.tree.map(lambda _: P(), tree),
            check_vma=False))

    a = shmap(agg.allreduce_tree)(tree)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        b = shmap(lambda t: legacy(t, ("data",), cfg))(tree)
    for k in a:
        av, bv = np.asarray(a[k]), np.asarray(b[k])
        assert np.array_equal(av.view(np.int32), bv.view(np.int32)), \
            (strategy, stacked, bucket_bytes, k)


MULTI_DEV_CODE = r"""
import itertools, warnings
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import allreduce as AR
from repro.core.agg import AggConfig, Aggregator

rng = np.random.default_rng(0)
mesh_flat = compat.make_mesh((8,), ("data",))
mesh_hier = compat.make_mesh((2, 4), ("pod", "data"))
tree = {f"l{i}": jnp.asarray(
    (rng.standard_normal((8, n)) * 0.01).astype(np.float32))
    for i, n in enumerate((1100, 300, 64))}

def run(body, t, hier):
    mesh = mesh_hier if hier else mesh_flat
    axes = ("pod", "data") if hier else ("data",)
    fn = jax.jit(compat.shard_map(
        lambda s: body(jax.tree.map(lambda x: x[0], s), axes), mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axes if hier else "data"), t),),
        out_specs=jax.tree.map(lambda _: P(), t), check_vma=False))
    return {k: np.asarray(v) for k, v in
            fn(jax.tree.map(lambda x: x.reshape((8, 1) + x.shape[1:]), t)).items()}

def assert_equal(a, b, tag):
    for k in a:
        assert np.array_equal(a[k].view(np.int32), b[k].view(np.int32)), (tag, k)

# flat + hierarchical meshes: facade == legacy. The numeric behavior of each
# strategy is pinned exhaustively by the existing suites (test_allreduce,
# test_bucketer, test_backend_parity); THIS sweep pins the facade's routing —
# one representative combo per dispatch path (flat / bucketed / hierarchical
# incl. narrow pod wire / host callback), each compiled twice (facade +
# legacy shim), to keep the 8-device compile count bounded.
combos = [  # (hier, strategy, bucket_bytes, pod_wire_bits)
    (False, "native", 0, None), (False, "switchml", 0, None),
    (False, "fpisa", 0, None), (False, "fpisa", 4096, None),
    (False, "fpisa_seq", 0, None), (False, "switch_emu", 0, None),
    (True, "fpisa", 0, None), (True, "fpisa", 4096, 16),
]
for hier, strat, bb, pw in combos:
    cfg = AggConfig(strategy=strat, backend="jnp", bucket_bytes=bb,
                    pod_wire_bits=pw)
    a = run(lambda t, axes: Aggregator(cfg, axes).allreduce_tree(t),
            tree, hier)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        b = run(lambda t, axes: AR.allreduce_tree(t, axes, cfg), tree, hier)
    assert_equal(a, b, (strat, bb, hier, pw))

# stacked (k=2 logical workers per shard, data-only mesh, W=16). The body
# drops run()'s singleton shard dim so every leaf enters as (k=2, n).
stree = jax.tree.map(lambda v: jnp.stack([v, v * 0.5], axis=1), tree)  # (8,2,n)
unstack = lambda t: jax.tree.map(lambda v: v[0], t)
for strat, bb in [("fpisa", 0), ("fpisa", 4096), ("switch_emu", 0)]:
    cfg = AggConfig(strategy=strat, backend="jnp", bucket_bytes=bb)
    a = run(lambda t, axes: Aggregator(cfg, axes, stacked=True)
            .allreduce_tree(unstack(t)), stree, False)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        b = run(lambda t, axes: AR.stacked_allreduce_tree(unstack(t), axes, cfg),
                stree, False)
    assert_equal(a, b, (strat, bb, "stacked"))
print("AGG_PARITY_OK")
"""


@pytest.mark.slow
def test_parity_facade_vs_legacy_multi_device(multi_device_runner):
    out = multi_device_runner(MULTI_DEV_CODE, n_devices=8, timeout=1800)
    assert "AGG_PARITY_OK" in out
