"""Gradient-aggregation strategies under shard_map on an 8-device host mesh
(subprocess — this process keeps 1 device per the project brief)."""
import numpy as np
import pytest


CODE = r"""
import numpy as np, jax
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import allreduce as AR

mesh = compat.make_mesh((2, 4), ("pod", "data"))
x = (np.random.default_rng(0).standard_normal((8, 5000)) * 0.01).astype(np.float32)
ref = x.astype(np.float64).sum(0)
scale = np.abs(ref).max()

def run(cfg):
    fn = jax.jit(compat.shard_map(lambda xs: AR.allreduce(xs[0], ("pod","data"), cfg),
                                  mesh=mesh, in_specs=P(("pod","data")), out_specs=P(),
                                  check_vma=False))
    return np.asarray(fn(x.reshape(8,1,5000)))

results = {}
for strat, wire, pw in [("native",32,None), ("switchml",32,None), ("fpisa",32,None),
                        ("fpisa",16,None), ("fpisa",32,16), ("fpisa_seq",32,None)]:
    out = run(AR.AggConfig(strategy=strat, wire_bits=wire, pod_wire_bits=pw))
    err = np.abs(out.astype(np.float64) - ref)
    results[f"{strat}-{wire}-{pw}"] = float(np.quantile(err, 0.99) / scale)

# error budgets per strategy (p99 relative to max-magnitude)
assert results["native-32-None"]   < 1e-6, results
assert results["switchml-32-None"] < 1e-5, results
assert results["fpisa-32-None"]    < 1e-6, results
assert results["fpisa-16-None"]    < 2e-3, results
assert results["fpisa-32-16"]      < 1e-3, results
assert results["fpisa_seq-32-None"]< 1e-5, results

# permutation invariance: FPISA integer path must be BIT-exact under any
# worker order (int add is associative+commutative) — the paper's
# reproducibility claim, strengthened to order-independence by our block path
cfg = AR.AggConfig(strategy="fpisa")
fn = jax.jit(compat.shard_map(lambda xs: AR.allreduce(xs[0], ("pod","data"), cfg),
                              mesh=mesh, in_specs=P(("pod","data")), out_specs=P(),
                              check_vma=False))
a = np.asarray(fn(x.reshape(8,1,5000)))
perm = np.random.default_rng(1).permutation(8)
b = np.asarray(fn(x[perm].reshape(8,1,5000)))
assert np.array_equal(a.view(np.int32), b.view(np.int32)), "fpisa not perm-invariant"
print("ALLREDUCE_OK")
"""


def test_allreduce_strategies_multi_device(multi_device_runner):
    out = multi_device_runner(CODE, n_devices=8, timeout=600)
    assert "ALLREDUCE_OK" in out


TRAIN_CODE = r"""
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
from repro.configs import get_smoke_config
from repro.models.registry import build
from repro.core.allreduce import AggConfig
from repro.optim import optimizers
from repro.sharding import rules
from repro.train.step import make_train_step
from repro.data.pipeline import SyntheticCorpus, ShardedLoader

# Modern jax: the production-shaped 3-axis mesh, exercising the PARTIALLY
# manual shard_map (manual replica axes + auto 'model') the real fleet uses.
# Old-jax XLA cannot partition that shape (SPMD IsManualSubgroup check
# failure), so there we fall back to a fully-manual pure-DP mesh — strategy
# equivalence itself is orthogonal to TP.
if hasattr(jax, "shard_map"):
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
else:
    mesh = compat.make_mesh((2, 4), ("pod", "data"))
cfg = get_smoke_config("internlm2-20b").with_(num_kv_heads=2, num_heads=8)
model = build(cfg)
params0 = model.init(jax.random.PRNGKey(0))
pspecs = rules.param_pspecs(params0, cfg, mesh)
opt_cfg = optimizers.OptConfig(name="adamw", lr=1e-3, warmup_steps=5)
ospecs = rules.opt_pspecs(pspecs, params0, mesh)
GB = 8
loader = ShardedLoader(SyntheticCorpus(cfg.vocab_size), GB, 64)
losses = {}
for strat in ["native", "fpisa", "switchml"]:
    params = jax.device_put(params0, rules.named(mesh, pspecs))
    opt = optimizers.init(params, opt_cfg)
    opt = optimizers.OptState(step=jax.device_put(opt.step, NamedSharding(mesh, P())),
                              m=jax.device_put(opt.m, rules.named(mesh, ospecs)),
                              v=jax.device_put(opt.v, rules.named(mesh, ospecs)))
    step = jax.jit(make_train_step(model, mesh, AggConfig(strategy=strat), opt_cfg, GB))
    ls = []
    for i in range(4):
        batch = {"tokens": jax.device_put(loader.batch_at(i)["tokens"],
                                          NamedSharding(mesh, P(("pod","data"), None)))}
        params, opt, m = step(params, opt, batch)
        ls.append(float(m["loss"]))
    losses[strat] = ls
# FPISA and SwitchML training must track native float training closely
for strat in ("fpisa", "switchml"):
    for a, b in zip(losses[strat], losses["native"]):
        assert abs(a - b) < 1e-3, (strat, losses)
# and the loss must decrease
assert losses["fpisa"][-1] < losses["fpisa"][0]
print("TRAIN_EQUIV_OK")
"""


def test_train_step_strategy_equivalence(multi_device_runner):
    out = multi_device_runner(TRAIN_CODE, n_devices=8, timeout=900)
    assert "TRAIN_EQUIV_OK" in out
