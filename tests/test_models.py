"""Per-architecture smoke tests (reduced configs, CPU, 1 device) — required
by the assignment: instantiate each arch family, run one forward/train step,
assert output shapes and no NaNs; plus prefill<->forward logits consistency
(a strong end-to-end correctness check for the serving path)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models.registry import build


def _batch(cfg, b=2, s=32, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(k, (b, cfg.num_patches, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(k, (b, cfg.num_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    s_total = batch["tokens"].shape[1] + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), f"{arch}: non-finite grad"

    # one SGD step moves the loss
    lr = 1e-2
    params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    loss2 = model.loss(params2, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_matches_forward_logits(arch):
    """Teacher-forcing consistency: prefill's last-token logits must equal the
    forward pass's last-position logits (same params, same inputs)."""
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, b=2, s=16, key=2)
    logits, _ = model.forward(params, batch)
    cache = model.init_cache(2, 48)
    plog, cache = model.prefill(params, batch, cache)
    np.testing.assert_allclose(
        np.asarray(plog[:, -1]), np.asarray(logits[:, -1]), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward_next_position(arch):
    """Append token t via decode_step; its logits must match a fresh forward
    pass over the extended sequence at the same position."""
    cfg = get_smoke_config(arch)
    if cfg.family == "vlm":
        pytest.skip("vlm decode consistency covered via dense family (patch prefix offsets positions)")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    s = 16
    batch = _batch(cfg, b=2, s=s, key=4)
    cache = model.init_cache(2, 48)
    _, cache = model.prefill(params, batch, cache)

    next_tok = jnp.asarray([[7], [11]], jnp.int32)
    dlog, cache = model.decode_step(params, next_tok, cache)

    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], next_tok], axis=1)
    flog, _ = model.forward(params, ext)
    np.testing.assert_allclose(
        np.asarray(dlog[:, -1]), np.asarray(flog[:, -1]), rtol=5e-3, atol=5e-3
    )
