"""Serving-path suite: continuous batching + paged KV cache (ISSUE 7).

Pins the three contracts the scheduler/allocator pair must keep:

1. admission edges — prompt == max_len, max_new == exact fit, zero-length
   and over-length prompts, whole-pool-infeasible requests;
2. paged-allocator invariants — no double-free, deterministic page reuse
   after retirement, pool exhaustion surfaces as queue backpressure (never a
   crash or a partial allocation);
3. bit-identity — the continuous engine's greedy per-request outputs equal
   the static engine's token for token (static run per request is the
   oracle: unpadded prompts at true positions), and the static engine's own
   slot-retirement optimization keeps batch rows identical to b=1 runs.
"""
import math
import warnings

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.core.agg import AggConfig
from repro.models.registry import build
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import PageAllocator, PagedKVCache, pages_needed
from repro.serve.loadgen import PoissonLoadGen, latency_report, percentile
from repro.serve.scheduler import ContinuousEngine


@pytest.fixture(scope="module")
def served():
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(rng, n, vocab):
    return rng.integers(0, vocab, n).astype(np.int32)


def _oracle(model, params, reqs, max_len):
    """Static engine, one request per run: the bit-identity reference."""
    out = {}
    for r in reqs:
        eng = ServeEngine(model, params, batch_size=1, max_len=max_len)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            res = eng.run([Request(r.rid, np.array(r.prompt), r.max_new_tokens)])
        if res:
            out[r.rid] = res[0].tokens
    return out


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------


def test_allocator_roundtrip_and_reuse():
    a = PageAllocator(num_pages=4, page_size=8)
    first = a.alloc(3)
    assert first == [1, 2, 3] and a.in_use == 3 and a.available == 1
    a.free([2])
    # freed page is reused, lowest id first — deterministic placement
    assert a.alloc(2) == [2, 4]
    assert a.in_use == 4 and a.peak_in_use == 4


def test_allocator_no_double_free():
    a = PageAllocator(num_pages=2, page_size=8)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(ValueError, match="double free"):
        a.free([pages[0]])
    with pytest.raises(ValueError, match="double free"):
        a.free([99])  # never-allocated id


def test_allocator_exhaustion_is_not_partial():
    a = PageAllocator(num_pages=3, page_size=8)
    assert a.alloc(2) is not None
    assert a.alloc(2) is None  # only 1 left: refuse whole request
    assert a.available == 1    # nothing was taken by the failed alloc
    assert a.alloc(1) is not None


def test_pages_needed():
    assert pages_needed(0, 8) == 0
    assert pages_needed(1, 8) == 1
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2


def test_paged_cache_shape_and_family_guards(served):
    cfg, model, params = served
    with pytest.raises(ValueError, match="must divide"):
        PagedKVCache(cfg, num_slots=2, max_len=30, page_size=8)
    ssm_cfg = get_smoke_config("mamba2-780m")
    with pytest.raises(ValueError, match="paged KV serving supports"):
        PagedKVCache(ssm_cfg, num_slots=2, max_len=32, page_size=8)


def test_paged_cache_slot_isolation(served):
    cfg, _, _ = served
    cache = PagedKVCache(cfg, num_slots=3, max_len=32, page_size=8)
    assert cache.grow_slot(0, 9)   # 2 pages
    assert cache.grow_slot(2, 17)  # 3 pages
    p0, p2 = set(cache.slot_pages(0)), set(cache.slot_pages(2))
    assert p0 and p2 and not (p0 & p2), "live slots must own disjoint pages"
    assert 0 not in p0 | p2, "scratch page 0 is never allocated"
    cache.release_slot(0)
    assert (cache.page_table[0] == 0).all()
    assert cache.pages_in_use == 3
    # released pages are available again
    assert cache.grow_slot(1, 32)  # 4 pages — needs the freed ones
    assert cache.pages_in_use == 7


def test_engine_requires_paged_decode_path(served):
    _, _, params = served
    ssm_model = build(get_smoke_config("mamba2-780m"))
    with pytest.raises(ValueError, match="no paged decode path"):
        ContinuousEngine(ssm_model, None, num_slots=2, max_len=32)


# ---------------------------------------------------------------------------
# admission edges
# ---------------------------------------------------------------------------


def test_admission_zero_length_prompt_rejected_both_engines(served):
    cfg, model, params = served
    bad = Request(rid=0, prompt=np.zeros((0,), np.int32), max_new_tokens=4)
    eng = ContinuousEngine(model, params, num_slots=2, max_len=16)
    with pytest.warns(UserWarning, match="zero-length"):
        assert eng.run([bad]) == []
    assert eng.telemetry["rejected"] == 1
    static = ServeEngine(model, params, batch_size=2, max_len=16)
    with pytest.warns(UserWarning, match="zero-length"):
        assert static.run([Request(0, np.zeros((0,), np.int32), 4)]) == []
    assert static.telemetry["rejected"] == 1


def test_admission_overlong_prompt_rejected(served):
    cfg, model, params = served
    rng = np.random.default_rng(0)
    eng = ContinuousEngine(model, params, num_slots=2, max_len=16)
    with pytest.warns(UserWarning, match="rejected"):
        out = eng.run([Request(0, _prompt(rng, 17, cfg.vocab_size), 2)])
    assert out == [] and eng.telemetry["rejected"] == 1


def test_admission_prompt_equals_max_len(served):
    """A full-cache prompt still yields its one prefill-logits token, with
    zero decode steps, identical to the static oracle."""
    cfg, model, params = served
    rng = np.random.default_rng(1)
    req = Request(rid=0, prompt=_prompt(rng, 16, cfg.vocab_size),
                  max_new_tokens=7)
    eng = ContinuousEngine(model, params, num_slots=2, max_len=16,
                           page_size=8)
    with pytest.warns(UserWarning, match="truncated to 1"):
        (res,) = eng.run([req])
    assert res.tokens.shape == (1,)
    assert eng.telemetry["decode_steps"] == 0
    oracle = _oracle(model, params, [req], max_len=16)
    np.testing.assert_array_equal(res.tokens, oracle[0])


def test_admission_max_new_exactly_fits(served):
    """max_new == max_len - plen + 1: admitted untruncated, fills the cache
    to the last position without overrun."""
    cfg, model, params = served
    rng = np.random.default_rng(2)
    req = Request(rid=3, prompt=_prompt(rng, 6, cfg.vocab_size),
                  max_new_tokens=11)  # 16 - 6 + 1
    eng = ContinuousEngine(model, params, num_slots=1, max_len=16,
                           page_size=4)
    (res,) = eng.run([req])
    assert res.tokens.shape == (11,)
    assert eng.telemetry["truncated"] == 0
    oracle = _oracle(model, params, [req], max_len=16)
    np.testing.assert_array_equal(res.tokens, oracle[3])


def test_admission_whole_pool_infeasible_rejected(served):
    """A request whose worst case exceeds the ENTIRE pool can never be
    scheduled — reject at submit instead of queueing it forever."""
    cfg, model, params = served
    rng = np.random.default_rng(3)
    eng = ContinuousEngine(model, params, num_slots=2, max_len=32,
                           page_size=8, num_pages=2)  # pool: 16 tokens
    with pytest.warns(UserWarning, match="whole pool"):
        out = eng.run([Request(0, _prompt(rng, 20, cfg.vocab_size), 4)])
    assert out == [] and eng.telemetry["rejected"] == 1


# ---------------------------------------------------------------------------
# backpressure: OOM becomes queueing, never a crash
# ---------------------------------------------------------------------------


def test_pool_exhaustion_backpressures_queue(served):
    """Pool of 4 pages (32 token positions) against 6 requests wanting
    ~13 positions each: admission throttles to what fits, every request
    still completes, and in-use never exceeds the pool."""
    cfg, model, params = served
    rng = np.random.default_rng(4)
    eng = ContinuousEngine(model, params, num_slots=3, max_len=32,
                           page_size=8, num_pages=4)
    reqs = [Request(rid=i, prompt=_prompt(rng, 8, cfg.vocab_size),
                    max_new_tokens=6) for i in range(6)]
    res = eng.run(reqs)
    assert sorted(r.rid for r in res) == list(range(6))
    assert eng.cache.peak_pages_in_use <= 4
    assert eng.cache.pages_in_use == 0  # everything released at retirement
    assert eng.telemetry["queue_peak"] >= 2  # backpressure actually queued
    oracle = _oracle(model, params, reqs, max_len=32)
    for r in res:
        np.testing.assert_array_equal(r.tokens, oracle[r.rid])


# ---------------------------------------------------------------------------
# bit-identity: continuous == static oracle
# ---------------------------------------------------------------------------


def test_continuous_matches_static_oracle_mixed_poisson(served):
    """The headline contract: greedy per-request outputs from the
    continuous engine equal the static engine's token for token on a mixed
    prompt/budget Poisson workload, while peak paged KV stays below the
    dense batch_size * max_len footprint."""
    cfg, model, params = served
    lg = PoissonLoadGen(rate=0.7, prompt_lens=(4, 8, 12), max_new=(2, 5, 9),
                        vocab_size=cfg.vocab_size, seed=7)
    trace = lg.trace(12)
    eng = ContinuousEngine(model, params, num_slots=4, max_len=32,
                           page_size=8)
    res = eng.run_trace([(t, r) for t, r in trace])
    assert len(res) == 12
    oracle = _oracle(model, params, [r for _, r in trace], max_len=32)
    for r in res:
        np.testing.assert_array_equal(r.tokens, oracle[r.rid])
    # paged footprint beats dense for this mixed workload
    assert eng.cache.peak_pages_in_use * 8 < eng.cache.dense_equivalent_tokens
    # latency accounting is complete and sane
    stats = eng.latency_stats()
    assert len(stats) == 12
    rep = latency_report(stats, slo_ttft=50.0)
    assert rep["ttft_p50"] >= 0 and rep["ttft_slo_attainment"] > 0


def test_static_engine_retirement_row_identity(served):
    """The static engine's slot retirement (decode batch shrinks as budgets
    finish) must not change any request's tokens: uniform-length batch rows
    == per-request runs, and slot_steps < b * max(effs) shows work actually
    stopped at each slot's own budget."""
    cfg, model, params = served
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=_prompt(rng, 6, cfg.vocab_size),
                    max_new_tokens=m) for i, m in enumerate((3, 8, 2, 5))]
    eng = ServeEngine(model, params, batch_size=4, max_len=32)
    out = {r.rid: r.tokens for r in eng.run(
        [Request(r.rid, np.array(r.prompt), r.max_new_tokens) for r in reqs])}
    oracle = _oracle(model, params, reqs, max_len=32)
    for rid, toks in out.items():
        np.testing.assert_array_equal(toks, oracle[rid])
    assert eng.telemetry["decode_steps"] == 7  # max(effs) - 1, unchanged
    # 4 slots x 7 lockstep steps = 28; retirement reduces live work to
    # sum(effs) - 4 = 14
    assert eng.telemetry["slot_steps"] == 14


def test_static_truncated_by_packing_counter(served):
    """Left-pad packing shrinking an admitted budget is now counted."""
    cfg, model, params = served
    rng = np.random.default_rng(6)
    long_p = Request(rid=0, prompt=_prompt(rng, 12, cfg.vocab_size),
                     max_new_tokens=5)
    short_p = Request(rid=1, prompt=_prompt(rng, 2, cfg.vocab_size),
                      max_new_tokens=8)  # admitted, then packed down to 5
    eng = ServeEngine(model, params, batch_size=2, max_len=16)
    res = eng.run([long_p, short_p])
    assert [r.tokens.shape for r in res] == [(5,), (5,)]
    assert eng.telemetry["truncated_by_packing"] == 1
    assert eng.telemetry["truncated"] == 0  # admission itself passed


def test_continuous_never_truncates_by_packing(served):
    """The continuous engine prefills unpadded, so the packing shrinkage the
    static engine must count simply cannot happen: the same short+long pair
    keeps the short request's full admitted budget."""
    cfg, model, params = served
    rng = np.random.default_rng(6)
    long_p = Request(rid=0, prompt=_prompt(rng, 12, cfg.vocab_size),
                     max_new_tokens=5)
    short_p = Request(rid=1, prompt=_prompt(rng, 2, cfg.vocab_size),
                      max_new_tokens=8)
    eng = ContinuousEngine(model, params, num_slots=2, max_len=16,
                           page_size=8)
    out = {r.rid: r.tokens for r in eng.run([long_p, short_p])}
    assert out[0].shape == (5,)
    assert out[1].shape == (8,)  # full budget — no batch-max packing cap


# ---------------------------------------------------------------------------
# telemetry through the facade (incl. shared multi-tenant dataplane)
# ---------------------------------------------------------------------------


def test_continuous_telemetry_through_facade(served):
    cfg, model, params = served
    rng = np.random.default_rng(8)
    reqs = [Request(rid=i, prompt=_prompt(rng, 5, cfg.vocab_size),
                    max_new_tokens=4) for i in range(5)]
    plain = ContinuousEngine(model, params, num_slots=2, max_len=16,
                             page_size=8)
    plain.run([Request(r.rid, np.array(r.prompt), r.max_new_tokens)
               for r in reqs])
    agg = ContinuousEngine(model, params, num_slots=2, max_len=16,
                           page_size=8, agg=AggConfig(strategy="fpisa"))
    agg.run(reqs)
    assert agg.aggregator is not None
    for key in ("requests", "tokens_generated", "decode_steps", "rejected"):
        assert agg.telemetry[key] == plain.telemetry[key], key


def test_continuous_telemetry_over_shared_multitenant_dataplane(served):
    """The serving engine rides a PR 6 shared dataplane as one tenant: its
    telemetry reductions land on the same named switch another job uses,
    counters stay exact, and the switch's per-job stats see serving traffic."""
    from repro import switchsim as ss

    cfg, model, params = served
    rng = np.random.default_rng(9)
    ss.reset_shared_dataplanes()
    try:
        reqs = [Request(rid=i, prompt=_prompt(rng, 5, cfg.vocab_size),
                        max_new_tokens=3) for i in range(3)]
        eng = ContinuousEngine(
            model, params, num_slots=2, max_len=16, page_size=8,
            agg=AggConfig(strategy="switch_emu", switch_shared="serve-test",
                          switch_jobs=2, switch_job=1))
        eng.run(reqs)
        assert eng.telemetry["requests"] == 3
        assert eng.telemetry["tokens_generated"] == 9
        w = jax.device_count()  # the telemetry mesh spans every device
        dp = ss.shared_dataplane(
            "serve-test",
            ss.DataplaneConfig(num_workers=w, num_slots=8,
                               elems_per_packet=256, fmt_name="fp32",
                               variant="fpisa_a", num_jobs=2,
                               job_workers=(w, w)))
        assert dp.job_stats[1]["packets"] > 0  # serving tenant really used it
    finally:
        ss.reset_shared_dataplanes()


# ---------------------------------------------------------------------------
# load generator
# ---------------------------------------------------------------------------


def test_loadgen_trace_shape_and_determinism():
    lg = PoissonLoadGen(rate=0.5, prompt_lens=(4, 8), max_new=(2, 6),
                        vocab_size=97, seed=11)
    a, b = lg.trace(20), lg.trace(20)
    assert len(a) == 20
    times = [t for t, _ in a]
    assert times == sorted(times) and times[0] > 0
    for (ta, ra), (tb, rb) in zip(a, b):  # same seed -> same trace
        assert ta == tb and ra.max_new_tokens == rb.max_new_tokens
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
    assert {len(r.prompt) for _, r in a} <= {4, 8}
    assert {r.max_new_tokens for _, r in a} <= {2, 6}
    assert all(r.prompt.max() < 97 for _, r in a)


def test_loadgen_mean_interarrival_tracks_rate():
    lg = PoissonLoadGen(rate=2.0, seed=0)
    times = [t for t, _ in lg.trace(600)]
    gaps = np.diff([0.0] + times)
    assert abs(gaps.mean() - 0.5) < 0.1  # 1/rate


def test_percentile_and_report_edges():
    assert math.isnan(percentile([], 50))
    assert percentile([1.0, math.nan, 3.0], 50) == 2.0
    rep = latency_report([], slo_ttft=1.0)
    assert math.isnan(rep["ttft_p50"]) and rep["n"] == 0
