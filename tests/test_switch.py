"""PISA switch-emulator protocol tests: exactly-once aggregation under loss,
determinism, SwitchML window discipline, overflow/overwrite accounting."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import fpisa as F
from repro.core import switch as sw

RNG = np.random.default_rng(7)


def _vec(w=8, n=1000, scale=0.01):
    return (RNG.standard_normal((w, n)) * scale).astype(np.float32)


def test_lossless_matches_sequential_reference_bits():
    vec = _vec()
    cfg = sw.SwitchConfig(num_workers=8, num_slots=16, elems_per_packet=64)
    out = sw.run_aggregation(sw.FpisaSwitch(cfg), vec)
    ref = np.asarray(F.fpisa_sum_sequential(jnp.asarray(np.pad(vec, ((0, 0), (0, 24))))))[:1000]
    assert np.array_equal(out.view(np.int32), ref.view(np.int32))


@pytest.mark.parametrize("drop", [0.1, 0.4])
def test_exactly_once_under_loss(drop):
    vec = _vec()
    cfg = sw.SwitchConfig(num_workers=8, num_slots=4, elems_per_packet=64)
    s = sw.FpisaSwitch(cfg)
    out = sw.run_aggregation(s, vec, drop_prob=drop, seed=3)
    # every (worker, chunk) contributed exactly once despite retransmissions
    nchunks = int(np.ceil(1000 / 64))
    assert s.stats["packets"] == 8 * nchunks
    assert s.stats["duplicates"] > 0  # loss actually exercised the dup path
    # result is a valid FPISA aggregation: error vs exact sum bounded
    exact = vec.astype(np.float64).sum(0)
    err = np.abs(out.astype(np.float64) - exact)
    assert np.quantile(err, 0.99) < 1e-6


def test_deterministic_under_identical_loss_pattern():
    vec = _vec()
    cfg = sw.SwitchConfig(num_workers=8, num_slots=4, elems_per_packet=64)
    a = sw.run_aggregation(sw.FpisaSwitch(cfg), vec, drop_prob=0.3, seed=11)
    b = sw.run_aggregation(sw.FpisaSwitch(cfg), vec, drop_prob=0.3, seed=11)
    assert np.array_equal(a.view(np.int32), b.view(np.int32))


def test_full_variant_switch():
    vec = _vec()
    cfg = sw.SwitchConfig(num_workers=8, num_slots=8, elems_per_packet=64, variant="full")
    out = sw.run_aggregation(sw.FpisaSwitch(cfg), vec)
    exact = vec.astype(np.float64).sum(0)
    err = np.abs(out.astype(np.float64) - exact)
    assert err.max() < 1e-5  # full FPISA: no overwrite error


def test_slot_window_recycling():
    # more chunks than slots forces recycling; aggregation must still complete
    vec = _vec(w=4, n=4096)
    cfg = sw.SwitchConfig(num_workers=4, num_slots=2, elems_per_packet=64)
    s = sw.FpisaSwitch(cfg)
    out = sw.run_aggregation(s, vec, drop_prob=0.2, seed=5)
    exact = vec.astype(np.float64).sum(0)
    assert np.quantile(np.abs(out - exact), 0.99) < 1e-6


def test_overwrite_stats_reported():
    # wide-exponent-range inputs trigger overwrite events, which are counted
    vec = (RNG.standard_normal((8, 256)) * np.exp2(RNG.integers(-20, 20, (8, 256)))).astype(np.float32)
    cfg = sw.SwitchConfig(num_workers=8, num_slots=8, elems_per_packet=64)
    s = sw.FpisaSwitch(cfg)
    sw.run_aggregation(s, vec)
    assert s.stats["overwrite"] > 0
