"""HLO analyzer units: trip-count multiplication, collective wire accounting,
slice-aware byte charging — the roofline numbers depend on these."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hloscan


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_match_unrolled():
    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y.sum()

    def unrolled(x, w):
        c = x
        for _ in range(10):
            c = jnp.tanh(c @ w)
        return c.sum()

    x = jnp.ones((128, 128))
    w = jnp.ones((128, 128))
    a = hloscan.analyze(_hlo(scanned, x, w), 1)
    b = hloscan.analyze(_hlo(unrolled, x, w), 1)
    # dot flops: 10 * 2 * 128^3 = 41.9M; scan and unroll must agree within 1%
    assert abs(a.flops - b.flops) / b.flops < 0.01
    assert a.flops > 10 * 2 * 128**3 * 0.99


def test_nested_scan_multiplies():
    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=4)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y.sum()

    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))
    a = hloscan.analyze(_hlo(nested, x, w), 1)
    expect = 12 * 2 * 64**3  # 3 * 4 iterations
    assert a.flops == pytest.approx(expect, rel=0.05)


def test_tuple_type_instructions_parse():
    """While ops with many-element tuple types contain /*index=N*/ comments
    that used to break the parser — 95-layer models depend on this."""
    def f(x):
        def body(carry, _):
            a, b, c, d, e, g = carry
            return (a + 1, b * 2, c @ c, d - 1, e, g), None
        init = tuple(jnp.ones((32, 32)) for _ in range(6))
        out, _ = jax.lax.scan(body, init, None, length=7)
        return out[2].sum()

    a = hloscan.analyze(_hlo(f, jnp.ones(())), 1)
    assert a.flops >= 7 * 2 * 32**3  # the in-loop matmul was found & multiplied


def test_dot_flops_formula():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jnp.ones((4, 32, 64))
    b = jnp.ones((4, 64, 16))
    an = hloscan.analyze(_hlo(f, a, b), 1)
    assert an.flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)


def test_shape_bytes_tuple():
    assert hloscan.shape_bytes("(f32[2,3], bf16[4])") == 24 + 8
    assert hloscan.shape_bytes("pred[5]") == 5
    assert hloscan.shape_bytes("s32[]") == 4
