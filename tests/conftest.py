import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with N host devices.

    Tests in THIS process must see exactly 1 device (per the project brief),
    so multi-device integration tests go through here.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env,
    )
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={res.returncode})\nstdout:\n{res.stdout}\nstderr:\n{res.stderr[-4000:]}"
        )
    return res.stdout


@pytest.fixture(scope="session")
def multi_device_runner():
    return run_with_devices
