"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracles,
swept over shapes and dtypes, asserting bit-exact agreement."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _data(r, b, scale_exp=True):
    x = RNG.standard_normal((r, b)).astype(np.float32)
    if scale_exp:
        x = x * np.exp2(RNG.integers(-12, 12, (r, b))).astype(np.float32)
    return x


SHAPES = [(8, 128), (256, 256), (300, 256), (1024, 128), (64, 512), (1, 256)]


@pytest.mark.parametrize("shape", SHAPES)
def test_extract_kernel_matches_ref(shape):
    x = _data(*shape)
    e_k, m_k, b_k = ops.extract(x)
    e_r, m_r, b_r = ref.extract_ref(jnp.asarray(x))
    assert np.array_equal(e_k, e_r)
    assert np.array_equal(m_k, m_r)
    assert np.array_equal(b_k, b_r)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("preshift", [0, 2])
def test_align_kernel_matches_ref(shape, preshift):
    x = _data(*shape)
    e, m, b = ref.extract_ref(jnp.asarray(x))
    a_k = ops.align(e, m, b, preshift=preshift)
    a_r = ref.align_ref(e, m, b, preshift)
    assert np.array_equal(a_k, a_r)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("preshift", [0, 2])
def test_decode_kernel_matches_ref(shape, preshift):
    x = _data(*shape)
    e, m, b = ref.extract_ref(jnp.asarray(x))
    a = ref.align_ref(e, m, b, preshift)
    d_k = ops.decode(a, b, preshift=preshift)
    d_r = ref.decode_ref(a, b, preshift)
    assert np.array_equal(np.asarray(d_k).view(np.int32), np.asarray(d_r).view(np.int32))


@pytest.mark.parametrize("w", [2, 8, 17])
@pytest.mark.parametrize("variant", ["fpisa_a", "full"])
def test_accum_kernel_matches_ref(w, variant):
    x = (RNG.standard_normal((w, 64, 256)) * 0.01).astype(np.float32)
    a_k = ops.accum(x, variant=variant)
    a_r = ref.accum_ref(jnp.asarray(x), variant=variant)
    assert np.array_equal(np.asarray(a_k).view(np.int32), np.asarray(a_r).view(np.int32))


def test_extract_fp16_format():
    x = _data(128, 256, scale_exp=False)
    e_k, m_k, b_k = ops.extract(x.astype(np.float16), fmt_name="fp16")
    e_r, m_r, b_r = ref.extract_ref(jnp.asarray(x, jnp.float16), __import__("repro.core.fpisa", fromlist=["FP16"]).FP16)
    assert np.array_equal(e_k, e_r)
    assert np.array_equal(m_k, m_r)


def test_kernel_pipeline_equals_core_block_path():
    """extract -> align -> decode chained == fpisa.block_encode/decode."""
    from repro.core import fpisa as F

    x = _data(64, 256)
    e, m, b = ops.extract(x)
    a = ops.align(e, m, b, preshift=1)
    out = ops.decode(a, b, preshift=1)

    flat = jnp.asarray(x)
    p = F.encode(flat)
    be = F.block_max_exponent(p.exp, 256)
    man = F.block_encode(flat, be, 256, 1)
    expect = F.block_decode(man, be, 256, 1)
    assert np.array_equal(np.asarray(out).view(np.int32), np.asarray(expect).view(np.int32))
