"""Span tracer contract (src/repro/trace, DESIGN.md §13): nesting and
ordering, tag propagation, the sync boundary, JSONL/chrome export schema
round-trips, ring-buffer capacity, and the near-zero disabled path — the
overhead bound that lets instrumentation live permanently on the hot paths
(agg facade, bucketer, switchsim, serve, controller)."""
import json
import threading
from time import perf_counter

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import trace
from repro.trace import export, tracer


@pytest.fixture(autouse=True)
def _clean_global():
    """Every test leaves the process-global tracer disabled."""
    yield
    trace.disable()


# ---------------------------------------------------------------------------
# span recording: nesting, ordering, tags
# ---------------------------------------------------------------------------


def test_nesting_parent_depth_and_order():
    tr = tracer.Tracer()
    with tr.span("outer", job=1):
        with tr.span("mid"):
            with tr.span("inner"):
                pass
        with tr.span("mid2"):
            pass
    spans = tr.spans
    # records land at span END -> innermost first, outer last
    assert [s["name"] for s in spans] == ["inner", "mid", "mid2", "outer"]
    by = {s["name"]: s for s in spans}
    assert by["outer"]["parent"] == -1 and by["outer"]["depth"] == 0
    assert by["mid"]["parent"] == by["outer"]["id"]
    assert by["inner"]["parent"] == by["mid"]["id"]
    assert by["inner"]["depth"] == 2
    assert by["mid2"]["parent"] == by["outer"]["id"]
    # children are contained in the parent's interval
    assert by["outer"]["ts"] <= by["inner"]["ts"]
    assert by["inner"]["ts"] + by["inner"]["dur"] \
        <= by["outer"]["ts"] + by["outer"]["dur"] + 1e-9


def test_tags_at_open_and_late_tag():
    tr = tracer.Tracer()
    with tr.span("s", bucket=3, phase="encode") as sp:
        sp.tag(rounds=7)
    (s,) = tr.spans
    assert s["tags"] == {"bucket": 3, "phase": "encode", "rounds": 7}


def test_sync_blocks_and_marks():
    tr = tracer.Tracer()
    with tr.span("s") as sp:
        out = sp.sync(jnp.arange(8) * 2)
    assert np.array_equal(np.asarray(out), np.arange(8) * 2)
    assert tr.spans[0]["synced"] is True
    with tr.span("t"):
        pass
    assert tr.spans[1]["synced"] is False


def test_sync_inside_jit_trace_is_not_marked():
    """Under a jit trace the value is a Tracer — sync must not block (it
    cannot) and must not claim the duration is a device time."""
    tr = tracer.Tracer()

    @jax.jit
    def f(x):
        with tr.span("inside") as sp:
            return sp.sync(x * 2)

    f(jnp.ones(4))
    inside = [s for s in tr.spans if s["name"] == "inside"]
    assert inside and all(not s["synced"] for s in inside)


def test_threads_get_independent_stacks():
    tr = tracer.Tracer()
    done = threading.Event()

    def worker():
        with tr.span("w"):
            done.wait(1.0)

    t = threading.Thread(target=worker)
    with tr.span("main"):
        t.start()
        done.set()
        t.join()
    by = {s["name"]: s for s in tr.spans}
    assert by["w"]["parent"] == -1  # not nested under main's span
    assert by["w"]["tid"] != by["main"]["tid"]


def test_ring_capacity_drops_oldest():
    tr = tracer.Tracer(capacity=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert [s["name"] for s in tr.spans] == ["s6", "s7", "s8", "s9"]
    assert tr.dropped == 6


# ---------------------------------------------------------------------------
# the global switch + disabled-path overhead
# ---------------------------------------------------------------------------


def test_global_enable_disable_round_trip():
    assert not trace.enabled()
    assert trace.span("x") is tracer.NULL_SPAN
    tr = trace.enable()
    assert trace.enabled() and trace.get() is tr
    with trace.span("y", k=1):
        pass
    assert tr.spans[0]["name"] == "y"
    trace.disable()
    assert not trace.enabled()
    with trace.span("z"):
        pass
    assert len(tr.spans) == 1  # nothing recorded after disable


def test_null_span_is_falsy_noop():
    sp = trace.span("whatever", a=1)
    assert not sp
    with sp as inner:
        inner.tag(b=2)
        assert inner.sync(123) == 123


def test_disabled_overhead_under_one_percent_of_agg_step():
    """The acceptance bound: leaving spans on the hot paths costs < 1% of a
    smoke-size fig11 aggregation step even if EVERY span site fired once per
    microsecond-scale phase.  Measured as: cost of a disabled span (enter +
    exit + sync) x a generous per-step span count vs the measured step."""
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core.agg import AggConfig, Aggregator

    rng = np.random.default_rng(0)
    tree = {f"l{i}": jnp.asarray((rng.standard_normal(n) * 0.01)
                                 .astype(np.float32))
            for i, n in enumerate((4096, 777, 2048))}
    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    agg = Aggregator(AggConfig(strategy="fpisa", backend="jnp",
                               bucket_bytes=1 << 16), ("data",))
    fn = jax.jit(compat.shard_map(
        agg.allreduce_tree, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), tree),),
        out_specs=jax.tree.map(lambda _: P(), tree), check_vma=False))
    jax.block_until_ready(fn(tree))
    t0 = perf_counter()
    iters = 5
    for _ in range(iters):
        jax.block_until_ready(fn(tree))
    step = (perf_counter() - t0) / iters

    assert not trace.enabled()
    n = 20000
    t0 = perf_counter()
    for _ in range(n):
        with trace.span("hot", phase="encode") as sp:
            sp.sync(None)
    per_span = (perf_counter() - t0) / n

    # spans inside jitted code (bucketer phases, agg facade under jit) exist
    # at TRACE time only — compiled steps cross zero of them; the Python-
    # level sites (switchsim driver, serve scheduler, controller, benchmark
    # timed()) are a handful per step.  32 is a >5x margin over that.
    spans_per_step = 32
    assert per_span * spans_per_step < 0.01 * step, (
        f"disabled span {per_span*1e9:.0f}ns x {spans_per_step} "
        f"not < 1% of step {step*1e6:.0f}us")


# ---------------------------------------------------------------------------
# export schema round-trips
# ---------------------------------------------------------------------------


def test_jsonl_round_trip_and_schema_header(tmp_path):
    tr = tracer.Tracer()
    with tr.span("a", phase="encode", elems=256) as sp:
        sp.sync(jnp.ones(4))
    path = tmp_path / "t.jsonl"
    export.write_jsonl(tr, path)
    header, spans = export.read_jsonl(path)
    assert header["schema"] == tracer.SCHEMA_VERSION
    assert header["kind"] == "repro-trace"
    assert header["clock"] == "perf_counter"
    assert len(spans) == 1
    rec = tr.spans[0]
    assert spans[0] == json.loads(json.dumps(rec))  # value-faithful


def test_read_jsonl_rejects_wrong_kind_and_schema(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"kind": "not-a-trace", "schema": 1}\n')
    with pytest.raises(ValueError, match="kind"):
        export.read_jsonl(p)
    p.write_text('{"kind": "repro-trace", "schema": 999}\n')
    with pytest.raises(ValueError, match="schema"):
        export.read_jsonl(p)


def test_chrome_export_shape(tmp_path):
    tr = tracer.Tracer()
    with tr.span("outer", phase="finish"):
        with tr.span("inner"):
            pass
    doc = export.to_chrome(tr)
    events = doc["traceEvents"]
    assert {e["name"] for e in events} == {"outer", "inner"}
    for e in events:
        assert e["ph"] == "X"
        assert e["ts"] >= 0 and e["dur"] >= 0  # microseconds
    outer = next(e for e in events if e["name"] == "outer")
    assert outer["cat"] == "finish"
    path = export.write_chrome(tr, tmp_path / "t.chrome.json")
    assert json.load(open(path))["traceEvents"]


# ---------------------------------------------------------------------------
# instrumented seams actually record
# ---------------------------------------------------------------------------


def test_aggregator_facade_emits_spans():
    from repro.core.agg import AggConfig, Aggregator

    trace.enable()
    agg = Aggregator(AggConfig(strategy="fpisa", backend="jnp"), ())
    agg.allreduce(jnp.ones(256))
    names = [s["name"] for s in trace.get().spans]
    assert "agg.allreduce" in names
    sp = next(s for s in trace.get().spans if s["name"] == "agg.allreduce")
    assert sp["tags"]["strategy"] == "fpisa"
    assert sp["synced"] is True


def test_switchsim_emits_rounds_tag():
    from repro import switchsim as ss
    from repro.core import switch as sw

    trace.enable()
    rng = np.random.default_rng(0)
    vecs = rng.standard_normal((2, 64)).astype(np.float32)
    s = sw.FpisaSwitch(sw.SwitchConfig(num_workers=2, num_slots=4,
                                       elems_per_packet=32))
    ss.run_aggregation(s, vecs, seed=1)
    spans = [s_ for s_ in trace.get().spans
             if s_["name"] == "switchsim.run_aggregation"]
    assert spans and spans[0]["tags"]["rounds"] >= 1
    assert spans[0]["tags"]["phase"] == "switch"
