"""Edge-case coverage for ``switchml_allreduce`` and ``_wire_shift``:
all-zero blocks, denormal inputs, single-worker (w=1) meshes, and
wire_bits=8 saturation. The bounds asserted here are the ones documented in
DESIGN.md §2.

Single-worker cases run in-process (this process keeps 1 device); the
8-worker cases run on 8 host devices in a subprocess.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import allreduce as AR
from repro.core import fpisa
from repro.core import numerics as nx
from repro.core.agg import Aggregator


# ---------------------------------------------------------------------------
# _wire_shift: pure-python invariants
# ---------------------------------------------------------------------------

WORKER_COUNTS = [1, 2, 3, 4, 7, 8, 9, 16, 64, 100, 1024, 4096]


@pytest.mark.parametrize("fmt", list(fpisa.FORMATS.values()), ids=lambda f: f.name)
def test_wire_shift_single_worker(fmt):
    # w=1: nothing to sum, so the 32-bit wire needs no pre-shift at all
    assert AR._wire_shift(fmt, 1, 32) == nx.required_preshift(1, fmt) == 0


@pytest.mark.parametrize("fmt", list(fpisa.FORMATS.values()), ids=lambda f: f.name)
@pytest.mark.parametrize("wire", [8, 16, 32])
def test_wire_shift_sum_never_overflows(fmt, wire):
    """The exact saturation invariant: the most extreme aligned mantissa is
    +-(2^(man_bits+1) - 1); after the arithmetic right shift by t, a sum over
    w workers must fit the signed wire integer — including the asymmetric
    negative end, which round-toward--inf pushes one past the positive end
    (e.g. fp32/w=8/wire=8: +15*8 = 120 vs -16*8 = -128 — exactly int8 min)."""
    prev = 0
    max_w = 2 ** 31 if wire >= 32 else 1 << (wire - 1)
    for w in [v for v in WORKER_COUNTS if v <= max_w]:
        t = AR._wire_shift(fmt, w, wire)
        mag = (1 << (fmt.man_bits + 1)) - 1
        hi = mag >> t                      # arshift of +mag
        lo = -((mag + (1 << t) - 1) >> t)  # arshift of -mag (floor = -ceil)
        assert w * hi <= 2 ** (wire - 1) - 1, (w, t)
        assert w * lo >= -(2 ** (wire - 1)), (w, t)
        assert t >= prev, "wire shift must be monotone in worker count"
        prev = t


@pytest.mark.parametrize("wire", [8, 16])
def test_wire_shift_refuses_unrepresentable_worker_counts(wire):
    """Past w = 2^(wire-1) workers, NO shift is safe: negative mantissas
    floor at -1 under arithmetic right shift, so a same-signed reduction can
    reach -w and wrap the wire dtype. _wire_shift must refuse loudly."""
    edge = 1 << (wire - 1)
    AR._wire_shift(fpisa.FP32, edge, wire)  # exactly on the rail: allowed
    with pytest.raises(ValueError, match="cannot carry"):
        AR._wire_shift(fpisa.FP32, edge + 1, wire)
    with pytest.raises(ValueError, match="cannot carry"):
        AR._wire_shift(fpisa.FP32, 1024, 8)


@pytest.mark.parametrize("wire", [8, 16, 32])
def test_wire_capacity_guard_shared_with_pod_hop(wire):
    """The same rail guards the narrow cross-pod wire (_hier_collect sums
    w_pod in-pod partials): 2^(wire-1) summands allowed, one more refused,
    a 32-bit wire unconstrained."""
    if wire >= 32:
        AR._check_wire_capacity(1 << 20, wire)  # never refuses
        return
    AR._check_wire_capacity(1 << (wire - 1), wire)
    with pytest.raises(ValueError, match="cannot carry"):
        AR._check_wire_capacity((1 << (wire - 1)) + 1, wire)


def test_wire_shift_matches_documented_bound():
    # wire >= 32 degenerates to the int32-register preshift
    for w in WORKER_COUNTS:
        assert AR._wire_shift(fpisa.FP32, w, 32) == nx.required_preshift(w)
    # narrower wires: w * 2^(man_bits + 1 - t) <= 2^(wire - 1)  (DESIGN.md §2)
    for wire in (8, 16):
        for w in [v for v in WORKER_COUNTS if v <= 1 << (wire - 1)]:
            t = AR._wire_shift(fpisa.FP32, w, wire)
            assert w * 2.0 ** (fpisa.FP32.man_bits + 1 - t) <= 2.0 ** (wire - 1)


# ---------------------------------------------------------------------------
# single-worker (w=1) aggregation edge cases, in-process
# ---------------------------------------------------------------------------


def _run_w1(x: np.ndarray, cfg: AR.AggConfig) -> np.ndarray:
    mesh = compat.make_mesh((1,), ("data",))
    fn = jax.jit(compat.shard_map(
        Aggregator(cfg, ("data",)).allreduce, mesh=mesh,
        in_specs=P(), out_specs=P(), check_vma=False))
    return np.asarray(fn(jnp.asarray(x)))


def test_switchml_single_worker_is_quantized_identity():
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(2048) * 0.01).astype(np.float32)
    out = _run_w1(x, AR.AggConfig(strategy="switchml"))
    # w=1, s=0: quantization to man_bits at the block max — tiny relative err
    np.testing.assert_allclose(out, x, rtol=0, atol=np.abs(x).max() * 2.0 ** -23)


def test_switchml_all_zero_blocks_exact_zero():
    out = _run_w1(np.zeros(1024, np.float32), AR.AggConfig(strategy="switchml"))
    assert not out.any() and not np.signbit(out).any()


def test_switchml_denormals_flush_to_zero():
    # denormals carry biased exponent 0: the block max-exponent is 0, there
    # is no finite scale, and SwitchML's fixed-point grid has no cell for
    # them — they must quantize to exactly 0, never NaN/garbage
    x = np.full(1024, 1e-42, np.float32)  # subnormal
    out = _run_w1(x, AR.AggConfig(strategy="switchml"))
    assert not out.any()
    assert np.isfinite(out).all()


def test_switchml_tiny_normal_blocks_survive():
    """Regression: blocks whose max is a small normal used to hit an inf
    scale factor (2^k with k up to ~150 overflows float32) and flush the
    whole block to zero through inf/NaN laundering. With the split-exp2
    scaling they quantize normally."""
    x = np.full(512, np.float32(1.5 * 2.0 ** -126))
    out = _run_w1(x, AR.AggConfig(strategy="switchml"))
    # 1.5 * 2^-126 sits exactly on the fixed-point grid: roundtrip is exact
    np.testing.assert_array_equal(out, x)


def test_switchml_mixed_zero_and_live_blocks():
    x = np.zeros(1024, np.float32)
    x[512:] = 0.25  # second block live, first block all-zero
    out = _run_w1(x, AR.AggConfig(strategy="switchml", block=512))
    assert not out[:512].any()
    np.testing.assert_array_equal(out[512:], x[512:])


def test_fpisa_single_worker_wire8_roundtrip():
    # w=1 with an 8-bit wire: the whole mantissa is truncated to fit 8 bits;
    # the error bound of DESIGN.md §2 still must hold elementwise
    rng = np.random.default_rng(1)
    x = (rng.standard_normal(2048) * 0.01).astype(np.float32)
    cfg = AR.AggConfig(strategy="fpisa", wire_bits=8)
    out = _run_w1(x, cfg)
    t = AR._wire_shift(fpisa.FP32, 1, 8)
    blocks = x.reshape(-1, cfg.block)
    bmax = np.frexp(np.abs(blocks).max(axis=1))[1] + 126  # biased exp of max
    ulp = 2.0 ** (bmax.astype(np.float64) - 127 - 23 + t)
    err = np.abs(out.reshape(-1, cfg.block).astype(np.float64) - blocks)
    assert (err <= 2 * ulp[:, None]).all()


# ---------------------------------------------------------------------------
# 8-worker edge cases (subprocess)
# ---------------------------------------------------------------------------

EDGE_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import allreduce as AR
from repro.core import fpisa

W = 8
mesh = compat.make_mesh((W,), ("data",))

def run(cfg, x):
    fn = jax.jit(compat.shard_map(lambda xs: AR.allreduce(xs[0], ("data",), cfg),
                                  mesh=mesh, in_specs=P("data"), out_specs=P(),
                                  check_vma=False))
    return np.asarray(fn(x.reshape(W, 1, -1))).reshape(-1)

# --- switchml: all-zero and denormal gradients aggregate to exact zero
for name, x in [("zeros", np.zeros((W, 2048), np.float32)),
                ("denormal", np.full((W, 2048), 1e-42, np.float32))]:
    out = run(AR.AggConfig(strategy="switchml"), x)
    assert np.isfinite(out).all(), name
    assert not out.any(), name

# --- switchml: mixed blocks — zero blocks stay zero, live blocks exact
x = np.zeros((W, 2048), np.float32)
x[:, 1024:] = 0.125
out = run(AR.AggConfig(strategy="switchml"), x)
assert not out[:1024].any()
np.testing.assert_array_equal(out[1024:], np.float32(W * 0.125))

# --- fpisa wire_bits=8 saturation: every worker contributes the most
# extreme representable mantissa, all the same sign — the wire-dtype sum
# lands exactly on the int8 rails without wrapping (DESIGN.md §2)
for sign in (+1.0, -1.0):
    big = np.float32(sign * (2.0 - 2.0 ** -23))  # mantissa 2^24 - 1
    x = np.full((W, 2048), big, np.float32)
    cfg = AR.AggConfig(strategy="fpisa", wire_bits=8)
    out = run(cfg, x)
    assert np.isfinite(out).all(), sign
    assert (np.sign(out) == sign).all(), "saturation must never flip sign"
    t = AR._wire_shift(fpisa.FP32, W, 8)
    ulp = 2.0 ** (127 - 127 - 23 + t)  # value of one truncated wire unit
    err = np.abs(out.astype(np.float64) - W * float(big))
    assert (err <= (W + 1) * ulp).all(), err.max()

# --- fpisa wire8, cancelling signs: the float sum is 0, but the floor
# (round-toward--inf) pre-shift is sign-asymmetric (+big -> 15 wire units,
# -big -> -16), so the integer sum is a small negative residual — bounded by
# one truncated wire unit per worker, NOT a wrapped garbage value
x = np.empty((W, 2048), np.float32)
x[0::2] = 2.0 - 2.0 ** -23
x[1::2] = -(2.0 - 2.0 ** -23)
out = run(AR.AggConfig(strategy="fpisa", wire_bits=8), x)
t = AR._wire_shift(fpisa.FP32, W, 8)
assert (np.abs(out) <= W * 2.0 ** (-23 + t)).all(), out

# --- all-zero + denormal through fpisa wire8 (bmax==0 everywhere)
for x in [np.zeros((W, 2048), np.float32),
          np.full((W, 2048), 1e-42, np.float32)]:
    out = run(AR.AggConfig(strategy="fpisa", wire_bits=8), x)
    assert not out.any()
print("WIRE_EDGE_OK")
"""


def test_edge_cases_multi_worker(multi_device_runner):
    out = multi_device_runner(EDGE_CODE, n_devices=8, timeout=600)
    assert "WIRE_EDGE_OK" in out
