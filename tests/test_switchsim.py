"""Batched multi-pipeline dataplane (repro/switchsim): bit-exactness vs the
per-packet emulator and the jnp FPISA reference, fault-injection property
sweep, stale/duplicate accounting, deferred-rank resubmission, and the
switch_emu all-reduce strategy."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro import switchsim as ss
from repro.core import fpisa as F
from repro.core import switch as sw

RNG = np.random.default_rng(99)


def _vec(w=4, n=1024, wide=False):
    v = RNG.standard_normal((w, n)) * 0.01
    if wide:
        v = v * np.exp2(RNG.integers(-12, 12, (w, n)))
    return v.astype(np.float32)


def _arranged(vec: np.ndarray, arrivals: dict, e: int) -> np.ndarray:
    """Rearrange (W, N) so row i holds, per chunk, the i-th arriving worker's
    payload — the switch-arrival order the jnp sequential reference needs."""
    w, n = vec.shape
    pad = (-n) % e
    v3 = np.pad(vec, ((0, 0), (0, pad))).reshape(w, -1, e)
    nchunks = v3.shape[1]
    out = np.empty_like(v3)
    for c in range(nchunks):
        perm = arrivals[c]
        assert len(perm) == w, "exactly-once violated"
        out[:, c] = v3[perm, c]
    return out.reshape(w, -1)


# ---------------------------------------------------------------------------
# parity: batched == per-packet legacy shim, bit for bit, same RNG stream
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("drop,seed", [(0.0, 0), (0.15, 3), (0.5, 11)])
def test_batched_matches_perpacket_bit_exact(drop, seed):
    vec = _vec(w=4, n=2048)
    kw = dict(num_workers=4, num_slots=4, elems_per_packet=64)
    dp = ss.BatchedDataplane(ss.DataplaneConfig(**kw, num_pipelines=1))
    legacy = sw.FpisaSwitch(sw.SwitchConfig(**kw))
    a = ss.run_aggregation(dp, vec, drop_prob=drop, seed=seed)
    b = ss.run_aggregation(legacy, vec, drop_prob=drop, seed=seed)
    assert np.array_equal(a.view(np.int32), b.view(np.int32))
    assert dp.stats["packets"] == legacy.stats["packets"]
    assert dp.stats["duplicates"] == legacy.stats["duplicates"]
    assert dp.stats["overwrite"] == legacy.stats["overwrite"]
    assert dp.stats["overflow"] == legacy.stats["overflow"]


# ---------------------------------------------------------------------------
# property sweep: drop_prob x seed x num_pipelines x variant — the batched
# aggregate is bit-exact vs the jnp reference replayed in arrival order
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["fpisa_a", "full"])
@pytest.mark.parametrize("pipelines", [1, 3])
@pytest.mark.parametrize("drop,seed", [(0.0, 0), (0.3, 7), (0.7, 13)])
def test_sweep_bit_exact_vs_jnp_reference(variant, pipelines, drop, seed):
    w, e = 4, 64
    vec = _vec(w=w, n=1024, wide=True)
    cfg = ss.DataplaneConfig(num_workers=w, num_slots=2, elems_per_packet=e,
                             num_pipelines=pipelines, variant=variant)
    dp = ss.BatchedDataplane(cfg)
    out, arrivals = ss.run_aggregation(dp, vec, drop_prob=drop, seed=seed,
                                       record_arrivals=True)
    # exactly-once under loss: every (worker, chunk) contributed exactly once
    nchunks = -(-1024 // e)
    assert dp.stats["packets"] == w * nchunks
    if drop >= 0.3:
        assert dp.stats["duplicates"] > 0  # loss actually exercised the path
    ref = np.asarray(F.fpisa_sum_sequential(
        jnp.asarray(_arranged(vec, arrivals, e)), variant=variant))[:1024]
    assert np.array_equal(out.view(np.int32), ref.view(np.int32))


def test_duplicate_heavy_and_all_drop_rounds():
    # drop_prob 0.9: most rounds lose most packets, many rounds lose ALL of a
    # worker's packets, and completed slots re-serve heavily — the aggregate
    # must still be exactly-once and bit-exact vs the replayed reference.
    w, e = 3, 32
    vec = _vec(w=w, n=128)
    cfg = ss.DataplaneConfig(num_workers=w, num_slots=2, elems_per_packet=e)
    dp = ss.BatchedDataplane(cfg)
    out, arrivals = ss.run_aggregation(dp, vec, drop_prob=0.9, seed=5,
                                       max_rounds=100_000, record_arrivals=True)
    assert dp.stats["packets"] == w * 4
    assert dp.stats["duplicates"] > 0
    ref = np.asarray(F.fpisa_sum_sequential(
        jnp.asarray(_arranged(vec, arrivals, e))))[:128]
    assert np.array_equal(out.view(np.int32), ref.view(np.int32))


# ---------------------------------------------------------------------------
# numpy dataplane (the jax-free switch_emu backend) == jitted dataplane
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", ["fpisa_a", "full"])
def test_numpy_dataplane_matches_jit(variant):
    vec = _vec(w=4, n=1024, wide=True)
    cfg = ss.DataplaneConfig(num_workers=4, num_slots=2, elems_per_packet=64,
                             num_pipelines=3, variant=variant)
    a = ss.run_aggregation(ss.BatchedDataplane(cfg), vec, drop_prob=0.3, seed=1)
    npdp = ss.NumpyDataplane(cfg)
    b = ss.run_aggregation(npdp, vec, drop_prob=0.3, seed=1)
    assert np.array_equal(a.view(np.int32), b.view(np.int32))
    assert npdp.stats["packets"] == 4 * 16


# ---------------------------------------------------------------------------
# stale vs duplicate accounting (regression: the pre-refactor emulator
# conflated stale-window retransmissions with duplicates)
# ---------------------------------------------------------------------------


def test_stale_counter_separate_from_duplicates():
    e = 8
    cfg = sw.SwitchConfig(num_workers=2, num_slots=1, elems_per_packet=e)
    s = sw.FpisaSwitch(cfg)
    pay = np.ones(e, np.float32)
    # chunks 0 and 1 complete, filling both physical slots of the double pool
    for c in (0, 1):
        assert s.ingest(sw.Packet(0, c, pay)) is None
        assert s.ingest(sw.Packet(1, c, pay)) is not None
    # chunk 2 claims chunk 0's recycled slot
    assert s.ingest(sw.Packet(0, 2, pay)) is None
    # a retransmission for chunk 0 is now STALE (slot recycled), not a dup
    assert s.ingest(sw.Packet(1, 0, pay)) is None
    assert s.stats["stale"] == 1
    assert s.stats["duplicates"] == 0
    # a true duplicate: chunk 1 completed and still owns its slot -> cached
    # result re-served, counted as duplicate
    res = s.ingest(sw.Packet(0, 1, pay))
    assert res is not None and np.array_equal(res.payload, 2 * pay)
    assert s.stats["duplicates"] == 1
    assert s.stats["stale"] == 1
    assert s.stats["packets"] == 5


# ---------------------------------------------------------------------------
# deferred resubmission: per-slot occupancy beyond the compiled round count
# ---------------------------------------------------------------------------


def test_rank_overflow_defers_and_preserves_order():
    w, e = 8, 16
    cfg = ss.DataplaneConfig(num_workers=w, num_slots=1, elems_per_packet=e,
                             rounds_per_call=2)  # force deferral: 8 > 2
    dp = ss.BatchedDataplane(cfg)
    vec = _vec(w=w, n=e)
    ready, results, accepted = dp.ingest_batch(
        np.arange(w), np.zeros(w, np.int64), vec)
    assert accepted.all() and ready[-1] and not ready[:-1].any()
    ref = np.asarray(F.fpisa_sum_sequential(jnp.asarray(vec)))
    assert np.array_equal(results[-1].view(np.int32), ref.view(np.int32))


# ---------------------------------------------------------------------------
# batched query kernels: bit-level order pinning
# ---------------------------------------------------------------------------


def test_topn_keep_matches_cmp_planes():
    from repro.db import query as q
    from repro.switchsim import query as swq

    vals = _vec(w=1, n=512, wide=True)[0]
    t = F.encode(jnp.float32(0.37))
    keep = np.asarray(swq.topn_keep(jnp.asarray(vals), t.exp, t.man))
    planes = F.encode(jnp.asarray(vals))
    ref = q._cmp_planes(planes, F.Planes(
        jnp.broadcast_to(t.exp, planes.exp.shape),
        jnp.broadcast_to(t.man, planes.man.shape)))
    np.testing.assert_array_equal(keep, ref)


def test_groupby_ingest_matches_sequential_reference():
    from repro.switchsim import query as swq

    nslots, rows = 4, 64
    keys = RNG.integers(0, nslots, rows).astype(np.int32)
    vals = (RNG.standard_normal(rows) * 10).astype(np.float32)
    order = np.argsort(keys, kind="stable")
    k, v = keys[order], vals[order]
    exp, man, since, deferred = swq.groupby_ingest(
        jnp.zeros(nslots, jnp.int32), jnp.zeros(nslots, jnp.int32),
        jnp.zeros(nslots, jnp.int32),
        jnp.asarray(k), jnp.asarray(v), jnp.ones(rows, bool),
        num_slots=nslots, rounds=64, flush_every=8)
    assert not bool(np.asarray(deferred).any())
    # python reference: per-slot sequential full-FPISA adds with the same
    # flush-every-8 register renormalization
    re = np.zeros(nslots, np.int32)
    rm = np.zeros(nslots, np.int32)
    rs = np.zeros(nslots, np.int32)
    for key, val in zip(k, v):
        planes = F.encode(jnp.float32(val))
        acc, _ = F.fpisa_add_full(
            F.Planes(jnp.int32(re[key]), jnp.int32(rm[key])), planes)
        re[key], rm[key] = int(acc.exp), int(acc.man)
        rs[key] += 1
        if rs[key] >= 8:
            p = F.encode(F.renormalize(F.Planes(jnp.int32(re[key]), jnp.int32(rm[key]))))
            re[key], rm[key], rs[key] = int(p.exp), int(p.man), 0
    np.testing.assert_array_equal(np.asarray(exp), re)
    np.testing.assert_array_equal(np.asarray(man), rm)
    np.testing.assert_array_equal(np.asarray(since), rs)


# ---------------------------------------------------------------------------
# switch_emu all-reduce strategy == fpisa_seq, bitwise (multi-device)
# ---------------------------------------------------------------------------


SWITCH_EMU_CODE = r"""
import numpy as np, jax
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import allreduce as AR

mesh = compat.make_mesh((2, 4), ("pod", "data"))
x = (np.random.default_rng(0).standard_normal((8, 2000)) * 0.01).astype(np.float32)

def run(cfg):
    fn = jax.jit(compat.shard_map(lambda xs: AR.allreduce(xs[0], ("pod","data"), cfg),
                                  mesh=mesh, in_specs=P(("pod","data")), out_specs=P(),
                                  check_vma=False))
    return np.asarray(fn(x.reshape(8,1,2000)))

a = run(AR.AggConfig(strategy="switch_emu"))
b = run(AR.AggConfig(strategy="fpisa_seq"))
assert np.array_equal(a.view(np.int32), b.view(np.int32)), "switch_emu != fpisa_seq"
err = np.abs(a.astype(np.float64) - x.astype(np.float64).sum(0))
assert np.quantile(err, 0.99) < 1e-5, err.max()
print("SWITCH_EMU_OK")
"""


def test_switch_emu_strategy_multi_device(multi_device_runner):
    out = multi_device_runner(SWITCH_EMU_CODE, n_devices=8, timeout=600)
    assert "SWITCH_EMU_OK" in out
