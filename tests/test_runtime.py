"""Fault-tolerance substrate: checkpointing, elastic resharding prerequisites,
health monitoring, deterministic data failover."""
import json
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import ShardedLoader, SyntheticCorpus, reassign_shard
from repro.runtime import checkpoint as ckpt
from repro.runtime.health import HealthMonitor


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t, extra={"loss": 1.25})
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored, extra = ckpt.restore(str(tmp_path), 5, t)
    assert extra == {"loss": 1.25}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t, keep=2)
    steps = sorted(ckpt.committed_steps(str(tmp_path)))
    assert steps == [4, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_corrupt_checkpoint_skipped(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 2, t)
    # corrupt the newest: remove a leaf file
    d = os.path.join(str(tmp_path), "step_2")
    victim = next(f for f in os.listdir(d) if f.endswith(".npy"))
    os.remove(os.path.join(d, victim))
    assert ckpt.latest_step(str(tmp_path)) == 1  # falls back to the valid one


def test_partial_write_never_visible(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate a crash mid-save: a .tmp dir without manifest
    os.makedirs(os.path.join(str(tmp_path), "step_9.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_async_checkpointer(tmp_path):
    t = _tree()
    ac = ckpt.AsyncCheckpointer(str(tmp_path))
    ac.save(3, t)
    ac.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_restore_dtype_and_shape_guard(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    bad = {"a": jnp.zeros((4, 4)), "nested": t["nested"]}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), 1, bad)


# --- data pipeline determinism & failover ---


def test_data_deterministic_per_step_and_shard():
    c = SyntheticCorpus(1000, seed=3)
    a = c.batch(7, 2, 4, 64)
    b = c.batch(7, 2, 4, 64)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c.batch(8, 2, 4, 64))
    assert not np.array_equal(a, c.batch(7, 3, 4, 64))


def test_shard_reassignment_reproduces_lost_stream():
    c = SyntheticCorpus(1000)
    dead = ShardedLoader(c, 16, 32, shard_id=3, num_shards=4)
    survivor = ShardedLoader(c, 16, 32, shard_id=0, num_shards=4)
    replacement = reassign_shard(survivor, new_shard_id=3)
    for step in (0, 5, 11):
        np.testing.assert_array_equal(
            dead.batch_at(step)["tokens"], replacement.batch_at(step)["tokens"]
        )


# --- health / straggler ---


def test_failure_detection_and_reassignment():
    t = [0.0]
    clock = lambda: t[0]
    hm = HealthMonitor(hosts=[0, 1, 2, 3], timeout=10.0, clock=clock)
    for h in range(4):
        hm.heartbeat(h, 1.0)
    t[0] = 5.0
    for h in (0, 1, 3):
        hm.heartbeat(h, 1.0)
    t[0] = 16.0  # host 2 silent for 16s > timeout
    for h in (0, 1, 3):
        hm.heartbeat(h, 1.0)
    res = hm.check()
    assert res["dead"] == [2]
    assert res["reassign"] == {2: 0}  # deterministic: lowest surviving id


def test_straggler_detection():
    t = [0.0]
    hm = HealthMonitor(hosts=[0, 1, 2, 3], timeout=100.0, straggler_factor=2.0,
                       clock=lambda: t[0])
    for _ in range(8):
        for h in range(4):
            hm.heartbeat(h, 1.0 if h != 3 else 5.0)  # host 3 is 5x slower
    res = hm.check()
    assert 3 in res["stragglers"]
    assert res["dead"] == []
