"""Sharding rules coverage: every parameter of every arch gets a VALID
PartitionSpec on the production mesh (all sharded dims divisible), and the
attention TP mode matches each arch's divisibility structure."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import ARCH_NAMES, get_config
from repro.launch import specs as S
from repro.models.registry import build
from repro.optim import optimizers
from repro.sharding import rules

MESH = compat.abstract_mesh((16, 16), ("data", "model"))
MESH_MP = compat.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, part):
    if part is None:
        return 1
    if isinstance(part, tuple):
        out = 1
        for p in part:
            out *= mesh.shape[p]
        return out
    return mesh.shape[part]


def _check_specs(tree, specs, mesh, where):
    flat_p = jax.tree.leaves(tree)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim, (where, leaf.shape, spec)
        for dim, part in zip(leaf.shape, list(spec)):
            size = _axis_size(mesh, part)
            assert dim % size == 0, (where, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["single", "multipod"])
def test_param_and_opt_specs_valid(arch, mesh):
    cfg = get_config(arch)
    model = build(cfg)
    p_sds = S.param_specs(model)
    pspecs = rules.param_pspecs(p_sds, cfg, mesh)
    _check_specs(p_sds, pspecs, mesh, arch)

    o_sds = S.opt_specs(p_sds, optimizers.OptConfig())
    ospecs = rules.opt_pspecs(pspecs, p_sds, mesh)
    _check_specs(o_sds.m, ospecs, mesh, arch + "/opt")


@pytest.mark.parametrize(
    "arch,expected",
    [
        ("qwen1.5-0.5b", "head"),
        ("internlm2-20b", "qhead"),
        ("deepseek-67b", "qhead"),
        ("stablelm-3b", "head"),
        ("arctic-480b", "hdim"),
        ("kimi-k2-1t-a32b", "qhead"),
        ("zamba2-7b", "head"),
        ("llava-next-34b", "hdim"),
        ("whisper-medium", "head"),
        ("mamba2-780m", "none"),
    ],
)
def test_attention_tp_modes(arch, expected):
    assert rules.attn_mode(get_config(arch), 16) == expected


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_big_params_are_sharded(arch):
    """Every leaf >= 64 MiB must be sharded on at least one mesh axis — a
    replicated multi-GB tensor is a memory bug at 1T scale. Known by-design
    exceptions: KV weights under Megatron KV duplication (qhead TP mode) and
    vocab tensors whose size does not divide the model axis (whisper)."""
    cfg = get_config(arch)
    model = build(cfg)
    p_sds = S.param_specs(model)
    pspecs = rules.param_pspecs(p_sds, cfg, mesh=MESH)
    flat, _ = rules._tree_paths(p_sds)
    flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    dt_bytes = lambda l: np.dtype(l.dtype).itemsize * int(np.prod(l.shape))
    mode = rules.attn_mode(cfg, 16)
    for (path, leaf), spec in zip(flat, flat_s):
        if dt_bytes(leaf) < 64 << 20:
            continue
        if mode == "qhead" and ("/wk" in path or "/wv" in path or "/bk" in path or "/bv" in path):
            continue  # Megatron KV duplication: replicated by design
        if cfg.vocab_size % 16 and ("embed/tok" in path or "head/w" in path):
            continue  # vocab not divisible by the model axis
        assert any(p is not None for p in spec), (arch, path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_cache_specs_valid(arch):
    from repro.configs import SHAPES

    cfg = get_config(arch)
    model = build(cfg)
    shape = SHAPES["decode_32k"]
    cache = S.cache_specs(model, shape.global_batch, shape.seq_len)
    cspecs = rules.cache_pspecs(cache, MESH, shape.global_batch, cfg)
    flat_c = [l for l in jax.tree.leaves(cache) if hasattr(l, "shape")]
    flat_s = jax.tree.leaves(cspecs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_c, flat_s):
        for dim, part in zip(leaf.shape, list(spec)):
            assert dim % _axis_size(MESH, part) == 0, (arch, leaf.shape, spec)
