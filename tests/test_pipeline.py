"""Pipeline parallelism: PP forward/loss must equal the plain (non-PP) model,
and gradients must flow through the ppermute schedule (subprocess, 4 devices)."""

CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models.registry import build
from repro.train.pipeline import make_pp_loss, split_stages

from repro import compat
# Modern jax: ('pod','model') mesh exercising the stage axis MANUAL with the
# TP axis auto. Old-jax XLA cannot SPMD-partition lax.axis_index (->
# PartitionId) inside a partially-auto shard_map, so there the test runs on a
# single-axis fully-manual mesh — the TP axis is orthogonal to the schedule.
if hasattr(jax, "shard_map"):
    mesh = compat.make_mesh((2, 2), ("pod", "model"))
else:
    mesh = compat.make_mesh((2,), ("pod",))
cfg = get_smoke_config("stablelm-3b").with_(num_layers=4, d_model=64)
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
staged = split_stages(params, 2)

batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}
ref_loss = float(model.loss(params, batch))

pp_loss = make_pp_loss(cfg, mesh, stage_axis="pod", n_micro=4)
got = float(jax.jit(pp_loss)(staged, batch))
assert abs(got - ref_loss) < 2e-3, (got, ref_loss)

# gradients flow and match the non-PP gradients
g_pp = jax.jit(jax.grad(pp_loss))(staged, batch)
g_ref = jax.grad(model.loss)(params, batch)
a = np.asarray(g_pp["layers"]["mlp"]["wi"]).reshape(4, 64, -1)
b = np.asarray(g_ref["layers"]["mlp"]["wi"])
assert np.allclose(a, b, rtol=2e-2, atol=2e-4), np.abs(a-b).max()
print("PP_OK")
"""


def test_pipeline_matches_reference(multi_device_runner):
    out = multi_device_runner(CODE, n_devices=4, timeout=900)
    assert "PP_OK" in out
