"""FPISA core numerics: bit-exact semantics vs a scalar Python reference,
plus hypothesis property tests of the invariants in DESIGN.md §7.

``hypothesis`` is optional: on environments without it the property tests are
skipped and a deterministic sweep over hand-picked boundary values (subnormal
edge, exponent extremes, rounding pivots) covers the same invariants.
"""
import struct

import numpy as np
import pytest

try:  # property tests are a bonus; the deterministic sweep always runs
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import fpisa as F
from repro.core import numerics as nx

# ---------------------------------------------------------------------------
# scalar Python reference (independent implementation pinning semantics)
# ---------------------------------------------------------------------------


def ref_encode(x: float):
    bits = struct.unpack("<I", struct.pack("<f", np.float32(x)))[0]
    sign = bits >> 31
    exp = (bits >> 23) & 0xFF
    man = bits & 0x7FFFFF
    if exp == 0:  # denormal flush
        return 0, 0
    if exp == 0xFF:  # clamp specials
        exp, man = 0xFE, 0x7FFFFF
    mag = man | 0x800000
    return exp, -mag if sign else mag


def ref_arshift(m, s):
    s = max(0, min(31, s))
    return m >> s  # python ints: arithmetic shift


def ref_fpisa_a_add(acc, inp, headroom=7):
    (ae, am), (ie, im) = acc, inp
    d = ie - ae
    if d <= 0:
        return ae, _wrap32(am + ref_arshift(im, -d))
    if d <= headroom:
        return ae, _wrap32(am + _wrap32(im << d))
    return ie, im  # overwrite


def ref_full_add(acc, inp):
    (ae, am), (ie, im) = acc, inp
    d = ie - ae
    if d <= 0:
        return ae, _wrap32(am + ref_arshift(im, -d))
    return ie, _wrap32(ref_arshift(am, d) + im)


def _wrap32(v):
    v &= 0xFFFFFFFF
    return v - (1 << 32) if v >= (1 << 31) else v


def ref_renorm(e, m):
    if m == 0:
        return 0.0
    neg = m < 0
    mag = abs(m)
    k = mag.bit_length() - 1
    shift = k - 23
    if shift >= 0:
        m2 = m >> shift  # round toward -inf
    else:
        m2 = m << -shift
    if abs(m2) >> 24:
        m2 >>= 1
        shift += 1
    e2 = e + shift
    if e2 <= 0:
        return 0.0
    if e2 >= 255:
        return float("inf") * (-1 if neg else 1)
    bits = ((1 if m2 < 0 else 0) << 31) | (e2 << 23) | (abs(m2) & 0x7FFFFF)
    return struct.unpack("<f", struct.pack("<I", bits))[0]


# ---------------------------------------------------------------------------
# deterministic fallback sweep (always runs — covers the property-test
# invariants on hand-picked boundary values when hypothesis is unavailable)
# ---------------------------------------------------------------------------

SWEEP = [float(np.float32(v)) for v in (
    0.0, -0.0, 1.0, -1.0, 1.5, -1.25, 2.0 / 3.0, np.pi, -np.e,
    2.0 ** -126, -(2.0 ** -126),        # smallest normals
    2.0 ** -24, -(2.0 ** -24),          # the round-toward--inf pivot
    1.0 + 2.0 ** -23, 1.0 - 2.0 ** -24,  # neighbouring-ULP values
    3.4028235e38, -3.4028235e38,        # max finite
    65504.0, 1e-30, -1e-30, 123456.789, -0.1, 512.0,
)]

ADD_VALS = [float(np.float32(v)) for v in (
    0.0, 1.0, -1.0, 1.5, -0.1, 2.0 ** -24, 512.0, -3e4, 2.0 ** -100, 1e30,
)]


@pytest.mark.parametrize("x", SWEEP + [float("inf"), float("-inf")])
def test_encode_matches_scalar_ref_sweep(x):
    p = F.encode(jnp.float32(x))
    re, rm = ref_encode(x)
    assert int(p.exp) == re and int(p.man) == rm


@pytest.mark.parametrize("x", SWEEP)
def test_roundtrip_bit_exact_sweep(x):
    p = F.encode(jnp.float32(x))
    y = F.renormalize(p)
    if x == 0.0:
        # switch registers hold signless zero: -0.0 round-trips to +0.0
        assert float(y) == 0.0
    else:
        assert np.float32(x).view(np.int32) == np.asarray(y).view(np.int32)


def test_add_matches_scalar_ref_sweep():
    for a in ADD_VALS:
        for b in ADD_VALS:
            pa, pb = F.encode(jnp.float32(a)), F.encode(jnp.float32(b))
            sa = (int(pa.exp), int(pa.man))
            sb = (int(pb.exp), int(pb.man))
            out, _ = F.fpisa_a_add(pa, pb)
            assert (int(out.exp), int(out.man)) == ref_fpisa_a_add(sa, sb), (a, b)
            out, _ = F.fpisa_add_full(pa, pb)
            assert (int(out.exp), int(out.man)) == ref_full_add(sa, sb), (a, b)


@pytest.mark.parametrize("vals", [
    [1.0, 2.0 ** -24, -1.0, 3.5],
    [0.0, 0.0, 1e-3, -1e-3, 512.0],
    [100.0, -100.0, 0.25, 2.0 ** -20, -0.75, 1e3],
    [-1e3, 1e3, -1e3, 1e3, 7.0],
])
def test_sequential_sum_matches_scalar_chain_sweep(vals):
    arr = jnp.asarray(np.asarray(vals, np.float32)[:, None])
    out = F.fpisa_sum_sequential(arr, variant="fpisa_a")
    acc = (0, 0)
    for v in vals:
        acc = ref_fpisa_a_add(acc, ref_encode(v))
    expect = ref_renorm(acc[0], acc[1])
    got = float(np.asarray(out)[0])
    assert got == pytest.approx(expect, abs=0) or (
        np.isinf(expect) and np.isinf(got)
    ), (vals, got, expect)


# ---------------------------------------------------------------------------
# hypothesis property tests (skipped without the package)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    finite_f32 = st.floats(
        allow_nan=False, allow_infinity=False, width=32,
    ).filter(lambda x: x == 0.0 or 2**-126 <= abs(x) <= float(np.float32(3.4e38)))

    @given(finite_f32)
    @settings(max_examples=300, deadline=None)
    def test_encode_matches_scalar_ref(x):
        p = F.encode(jnp.float32(x))
        re, rm = ref_encode(x)
        assert int(p.exp) == re and int(p.man) == rm

    @given(finite_f32)
    @settings(max_examples=300, deadline=None)
    def test_roundtrip_bit_exact(x):
        p = F.encode(jnp.float32(x))
        y = F.renormalize(p)
        if x == 0.0:
            # switch registers hold signless zero: -0.0 round-trips to +0.0
            assert float(y) == 0.0
        else:
            assert np.float32(x).view(np.int32) == np.asarray(y).view(np.int32)

    @given(finite_f32, finite_f32)
    @settings(max_examples=300, deadline=None)
    def test_fpisa_a_add_matches_scalar_ref(a, b):
        pa, pb = F.encode(jnp.float32(a)), F.encode(jnp.float32(b))
        out, _ = F.fpisa_a_add(pa, pb)
        re, rm = ref_fpisa_a_add((int(pa.exp), int(pa.man)), (int(pb.exp), int(pb.man)))
        assert (int(out.exp), int(out.man)) == (re, rm)

    @given(finite_f32, finite_f32)
    @settings(max_examples=300, deadline=None)
    def test_full_add_matches_scalar_ref(a, b):
        pa, pb = F.encode(jnp.float32(a)), F.encode(jnp.float32(b))
        out, _ = F.fpisa_add_full(pa, pb)
        re, rm = ref_full_add((int(pa.exp), int(pa.man)), (int(pb.exp), int(pb.man)))
        assert (int(out.exp), int(out.man)) == (re, rm)

    @given(st.lists(st.floats(min_value=-1e3, max_value=1e3, width=32),
                    min_size=2, max_size=12))
    @settings(max_examples=100, deadline=None)
    def test_sequential_sum_matches_scalar_chain(vals):
        vals = [v if abs(v) >= 2**-120 else 0.0 for v in vals]
        arr = jnp.asarray(np.asarray(vals, np.float32)[:, None])
        out = F.fpisa_sum_sequential(arr, variant="fpisa_a")
        acc = (0, 0)
        for v in vals:
            acc = ref_fpisa_a_add(acc, ref_encode(v))
        expect = ref_renorm(acc[0], acc[1])
        got = float(np.asarray(out)[0])
        assert got == pytest.approx(expect, abs=0) or (
            np.isinf(expect) and np.isinf(got)
        ), (vals, got, expect)


def test_full_add_exact_when_no_truncation():
    # values with identical exponents: mantissa add is exact
    a = np.float32(1.5)
    b = np.float32(1.25)
    out = F.renormalize(F.fpisa_add_full(F.encode(a), F.encode(b))[0])
    assert float(out) == 2.75


def test_full_add_round_toward_neg_inf():
    # 1.0 + 2^-24 truncates the shifted-out bit -> exactly 1.0
    out = F.renormalize(F.fpisa_add_full(F.encode(np.float32(1.0)), F.encode(np.float32(2**-24)))[0])
    assert float(out) == 1.0
    # -1.0 - 2^-24 rounds toward -inf -> next value BELOW -1.0
    out = F.renormalize(F.fpisa_add_full(F.encode(np.float32(-1.0)), F.encode(np.float32(-(2**-24))))[0])
    assert float(out) < -1.0


def test_overwrite_error_bounded():
    # acc = small, incoming 2^8 larger -> overwrite; error == dropped acc value
    small, big = np.float32(1.0), np.float32(512.0)
    out, st_ = F.fpisa_a_add(F.encode(small), F.encode(big))
    assert bool(st_.overwrite)
    assert float(F.renormalize(out)) == 512.0  # small was dropped (paper Sec 4.3)


def test_fpisa_a_left_shift_exact_within_headroom():
    # incoming larger by <= 2^7: left shift is exact
    out, st_ = F.fpisa_a_add(F.encode(np.float32(1.0)), F.encode(np.float32(64.0)))
    assert not bool(st_.overwrite)
    assert float(F.renormalize(out)) == 65.0


def test_zero_accumulator_first_write_not_an_error():
    zero = F.Planes(exp=jnp.int32(0), man=jnp.int32(0))
    out, st_ = F.fpisa_a_add(zero, F.encode(np.float32(3.5)))
    assert not bool(st_.overwrite)
    assert float(F.renormalize(out)) == 3.5


@pytest.mark.parametrize("fmt", [F.FP32, F.FP16, F.BF16])
def test_roundtrip_formats(fmt):
    rng = np.random.default_rng(0)
    dtype = {"fp32": np.float32, "fp16": np.float16, "bf16": None}[fmt.name]
    if fmt.name == "bf16":
        x = jnp.asarray(rng.standard_normal(512), jnp.bfloat16)
    else:
        x = rng.standard_normal(512).astype(dtype)
        # flush values below the format's normal range
        x = np.where(np.abs(x.astype(np.float64)) < 2.0 ** (1 - fmt.bias), 0, x).astype(dtype)
        x = jnp.asarray(x)
    y = F.renormalize(F.encode(x, fmt), fmt)
    assert jnp.all((y == x) | (jnp.isnan(x))), fmt.name


def test_block_roundtrip_and_bound():
    rng = np.random.default_rng(1)
    x = (rng.standard_normal(4096) * 0.1).astype(np.float32)
    p = F.encode(x)
    be = F.block_max_exponent(p.exp, 256)
    for s in (0, 2):
        m = F.block_encode(x, be, 256, s)
        back = np.asarray(F.block_decode(m, be, 256, s), np.float64)
        # error bounded by one ULP at the (block max exponent + preshift) scale
        bound = np.exp2(np.repeat(np.asarray(be), 256) - 127 - 23 + s)
        assert np.all(np.abs(back - x) <= bound + 1e-30)


def test_required_preshift():
    assert nx.required_preshift(128) == 0  # 7 headroom bits = 128 adds
    assert nx.required_preshift(256) == 1
    assert nx.required_preshift(512) == 2
    assert nx.required_preshift(2) == 0


def test_clz32():
    vals = np.asarray([1, 2, 3, 255, 2**23, 2**31 - 1, 0], np.uint32)
    got = np.asarray(nx.clz32(jnp.asarray(vals.view(np.int32))))
    expect = np.asarray([31, 30, 30, 24, 8, 1, 32])
    assert np.array_equal(got, expect)
