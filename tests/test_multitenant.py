"""Multi-tenant switch sharing with QoS-aware slot admission (DESIGN.md §10).

Pins the tentpole invariants:

* **single-tenant equivalence** — with ``num_jobs=1`` (or equal disjoint
  quotas and no contention) every dataplane (batched jit, per-packet,
  numpy mirror) is bit-identical to the pre-tenancy behavior, including the
  seeded-RNG stream of the round drivers;
* **admission semantics** — fresh foreign slots deny, stale completed slots
  are takeover-recycled (never "preempted"), stale in-flight slots are
  preempted with the loss charged to the victim's per-job counters;
* **per-job reclamation** — a dead worker's reclamation resets only its own
  job's in-flight slots;
* the shared-dataplane registry + ``switch_emu`` tenancy wiring, and a
  query stream (``db.query.StreamedGroupBySum``) riding the same switch as
  a training job.
"""
import numpy as np
import pytest

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro import switchsim as ss
from repro.db import query as Q
from repro.switchsim.dataplane import COUNTERS


def _vec(w, n, seed, scale=0.01):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((w, n)) * scale).astype(np.float32)


def _bits(a):
    return np.ascontiguousarray(np.asarray(a, np.float32)).view(np.int32)


class PerPacketLeg:
    """Per-packet dataplane leg for the driver-parity tests: every packet of
    a round goes through its own one-packet ``ingest_batch`` dispatch (the
    ``core.switch.FpisaSwitch`` view, but tenancy-aware). Per-slot processing
    is sequential in both dataplanes, so this must be bit-identical to the
    one-dispatch batched path."""

    def __init__(self, cfg):
        self.dp = ss.BatchedDataplane(cfg)
        self.cfg = cfg

    def ingest_batch(self, workers, chunks, payloads, jobs=None, now=0):
        b = len(workers)
        jobs = np.zeros(b, np.int32) if jobs is None else np.asarray(jobs)
        ready = np.zeros(b, bool)
        results = np.zeros((b, self.cfg.elems_per_packet), np.float32)
        accepted = np.zeros(b, bool)
        for i in range(b):
            r, res, acc = self.dp.ingest_batch(
                [workers[i]], [chunks[i]], np.asarray(payloads)[i][None],
                jobs=[int(jobs[i])], now=now)
            ready[i], results[i], accepted[i] = r[0], res[0], acc[0]
        return ready, results, accepted

    def reclaim_worker(self, worker, job=0):
        self.dp.reclaim_worker(worker, job)

    @property
    def job_stats(self):
        return self.dp.job_stats


# ---------------------------------------------------------------------------
# slot mapping + lottery
# ---------------------------------------------------------------------------


def test_slot_of_tenant_single_job_matches_legacy():
    cfg = ss.DataplaneConfig(num_workers=4, num_slots=8, num_pipelines=2)
    chunks = np.arange(256)
    np.testing.assert_array_equal(
        ss.slot_of_tenant(cfg, np.zeros(256, np.int64), chunks),
        ss.slot_of(cfg, chunks))


def test_slot_of_tenant_disjoint_quotas_partition_the_pool():
    cfg = ss.DataplaneConfig(num_workers=4, num_slots=8, num_pipelines=2,
                             num_jobs=2, job_slots=(4, 4), job_workers=(4, 4))
    chunks = np.arange(512)
    s0 = set(ss.slot_of_tenant(cfg, np.zeros(512, np.int64), chunks).tolist())
    s1 = set(ss.slot_of_tenant(cfg, np.ones(512, np.int64), chunks).tolist())
    assert s0.isdisjoint(s1)
    assert len(s0) == len(s1) == 2 * 4 * 2  # double pool x quota x pipelines


def test_lottery_deterministic_and_weight_proportional():
    cfg = ss.DataplaneConfig(num_workers=4, num_slots=4, num_jobs=3,
                             job_workers=(2, 1, 1), job_weights=(6, 3, 1))
    draws = np.stack([np.asarray(ss.lottery_pref(cfg, now))
                      for now in range(400)])
    again = np.stack([np.asarray(ss.lottery_pref(cfg, now))
                      for now in range(400)])
    np.testing.assert_array_equal(draws, again)  # deterministic in (slot,now)
    # jnp evaluation (the jitted kernel's path) agrees with numpy
    np.testing.assert_array_equal(
        np.asarray(ss.lottery_pref(cfg, 17, jnp)),
        ss.lottery_pref(cfg, 17, np))
    counts = np.bincount(draws.reshape(-1), minlength=3)
    frac = counts / counts.sum()
    # weighted 6:3:1 — generous tolerance, the hash is only pseudo-uniform
    assert frac[0] > frac[1] > frac[2]
    assert abs(frac[0] - 0.6) < 0.1 and abs(frac[2] - 0.1) < 0.07


# ---------------------------------------------------------------------------
# single-tenant equivalence (the tentpole invariant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("drop,seed", [(0.0, 0), (0.3, 7)])
def test_j1_bit_parity_across_all_dataplanes(drop, seed):
    """With one tenant, run_multitenant must consume the seeded RNG stream
    identically to run_aggregation and produce bit-identical results on the
    batched, per-packet, and numpy dataplanes."""
    cfg = ss.DataplaneConfig(num_workers=4, num_slots=4, elems_per_packet=64,
                             num_pipelines=2)
    vec = _vec(4, 1024, seed=3)
    want = ss.run_aggregation(ss.BatchedDataplane(cfg), vec,
                              drop_prob=drop, seed=seed)
    for leg in (ss.BatchedDataplane, ss.NumpyDataplane, PerPacketLeg):
        (got,), rep = ss.run_multitenant(leg(cfg), [vec],
                                         drop_prob=drop, seed=seed)
        np.testing.assert_array_equal(_bits(got), _bits(want), err_msg=leg.__name__)
        assert rep["done_round"][0] == rep["rounds"]


def test_equal_quota_no_contention_bit_parity():
    """Equal disjoint quotas + no contention: each tenant's run is
    bit-identical to an isolated single-tenant switch sized to its quota."""
    cfgJ2 = ss.DataplaneConfig(num_workers=4, num_slots=8, elems_per_packet=64,
                               num_jobs=2, job_slots=(4, 4), job_workers=(4, 4))
    cfg1 = ss.DataplaneConfig(num_workers=4, num_slots=4, elems_per_packet=64)
    va, vb = _vec(4, 2048, seed=1), _vec(4, 2048, seed=2)
    ia = ss.run_aggregation(ss.BatchedDataplane(cfg1), va)
    ib = ss.run_aggregation(ss.BatchedDataplane(cfg1), vb)
    for leg in (ss.BatchedDataplane, ss.NumpyDataplane):
        (fa, fb), rep = ss.run_multitenant(leg(cfgJ2), [va, vb])
        np.testing.assert_array_equal(_bits(fa), _bits(ia), err_msg=leg.__name__)
        np.testing.assert_array_equal(_bits(fb), _bits(ib), err_msg=leg.__name__)
        for s in rep["job_stats"]:
            assert s["admission_denied"] == 0 and s["preempted"] == 0


def test_contention_batched_numpy_bit_parity_and_stats():
    """Under real contention (full-overlap quotas, drops) the batched jit
    and numpy dataplanes stay bit-identical, including per-job counters."""
    cfg = ss.DataplaneConfig(num_workers=9, num_slots=8, elems_per_packet=64,
                             num_jobs=3, job_workers=(4, 4, 1),
                             job_priorities=(1, 0, 0), job_weights=(2, 1, 1))
    vs = [_vec(4, 2048, 1), _vec(4, 2048, 2), _vec(1, 512, 3)]
    fb, repb = ss.run_multitenant(ss.BatchedDataplane(cfg), vs,
                                  drop_prob=0.2, seed=5)
    fn, repn = ss.run_multitenant(ss.NumpyDataplane(cfg), vs,
                                  drop_prob=0.2, seed=5)
    for x, y in zip(fb, fn):
        np.testing.assert_array_equal(_bits(x), _bits(y))
    assert repb["job_stats"] == repn["job_stats"]
    assert repb["done_round"] == repn["done_round"]
    # the shared pool is oversubscribed: somebody must have been denied
    assert sum(s["admission_denied"] for s in repb["job_stats"]) > 0
    # each tenant's aggregate is still a correct FPISA sum of its own workers
    for f, v in zip(fb, vs):
        ref = v.astype(np.float64).sum(0)
        assert np.max(np.abs(np.asarray(f, np.float64) - ref)) < 0.1


def test_run_multitenant_validates_port_counts():
    cfg = ss.DataplaneConfig(num_workers=3, num_slots=4, elems_per_packet=64,
                             num_jobs=2, job_workers=(2, 1))
    with pytest.raises(AssertionError):
        ss.run_multitenant(ss.NumpyDataplane(cfg),
                           [_vec(2, 128, 0), _vec(2, 128, 1)])


# ---------------------------------------------------------------------------
# admission semantics (both dataplanes, lockstep)
# ---------------------------------------------------------------------------

_ADM_CFG = dict(num_workers=2, num_slots=2, elems_per_packet=4,
                num_jobs=2, job_workers=(2, 2), job_priorities=(0, 1),
                stale_after=3)


@pytest.mark.parametrize("leg", [ss.BatchedDataplane, ss.NumpyDataplane])
def test_fresh_foreign_slot_denied_and_cache_still_served(leg):
    cfg = ss.DataplaneConfig(**_ADM_CFG)
    dp = leg(cfg)
    p = np.ones((1, 4), np.float32)
    r, res, _ = dp.ingest_batch([0, 1], [0, 0], np.vstack([p, 2 * p]),
                                jobs=[0, 0], now=0)
    assert list(r) == [False, True]  # job0's chunk completes
    # a foreign packet hitting the FRESH completed slot is denied...
    r, _, acc = dp.ingest_batch([0], [0], 3 * p, jobs=[1], now=1)
    assert not r[0] and not acc[0]
    assert dp.job_stats[1]["admission_denied"] == 1
    # ...and the owner's retransmission is still served from the cache
    r, res, _ = dp.ingest_batch([0], [0], p, jobs=[0], now=2)
    assert r[0]
    np.testing.assert_allclose(np.asarray(res)[0], 3.0)


@pytest.mark.parametrize("leg", [ss.BatchedDataplane, ss.NumpyDataplane])
def test_stale_completed_slot_is_takeover_not_preemption(leg):
    """Recycling a stale COMPLETED slot is a takeover: the cached result is
    released, but no preemption is charged — preemption only ever applies to
    in-flight slots (a completed slot's result is never 'preempted')."""
    cfg = ss.DataplaneConfig(**_ADM_CFG)
    dp = leg(cfg)
    p = np.ones((1, 4), np.float32)
    dp.ingest_batch([0, 1], [0, 0], np.vstack([p, 2 * p]), jobs=[0, 0], now=0)
    # past stale_after, the higher-priority tenant claims the slot
    r, _, acc = dp.ingest_batch([0], [0], 3 * p, jobs=[1], now=6)
    assert acc[0] and not r[0]
    assert [s["preempted"] for s in dp.job_stats] == [0, 0]
    # the takeover started a fresh in-flight window for job1
    r, res, _ = dp.ingest_batch([1], [0], 4 * p, jobs=[1], now=6)
    assert r[0]
    np.testing.assert_allclose(np.asarray(res)[0], 7.0)


@pytest.mark.parametrize("leg", [ss.BatchedDataplane, ss.NumpyDataplane])
def test_inflight_preemption_charged_to_victim(leg):
    cfg = ss.DataplaneConfig(**_ADM_CFG)
    dp = leg(cfg)
    p = np.ones((1, 4), np.float32)
    # job0 parks an in-flight window (1 of 2 bitmap bits)
    dp.ingest_batch([0], [2], p, jobs=[0], now=0)
    # fresh in-flight: even the higher-priority tenant must wait
    r, _, acc = dp.ingest_batch([0], [2], 5 * p, jobs=[1], now=1)
    assert not acc[0]
    assert dp.job_stats[0]["preempted"] == 0
    # ...until the window goes stale, then it is preempted, charged to job0
    _, _, acc = dp.ingest_batch([0], [2], 5 * p, jobs=[1], now=20)
    assert acc[0]
    assert dp.job_stats[0]["preempted"] == 1
    assert dp.job_stats[1]["preempted"] == 0


@pytest.mark.parametrize("leg", [ss.BatchedDataplane, ss.NumpyDataplane])
def test_per_job_reclaim_only_resets_owner_jobs_slots(leg):
    cfg = ss.DataplaneConfig(num_workers=2, num_slots=2, elems_per_packet=4,
                             num_jobs=2, job_slots=(1, 1), job_workers=(2, 2))
    dp = leg(cfg)
    p = np.ones((1, 4), np.float32)
    # both jobs park an in-flight window (worker 0 each, disjoint slots)
    dp.ingest_batch([0], [0], p, jobs=[0], now=0)
    dp.ingest_batch([0], [0], 2 * p, jobs=[1], now=0)
    dp.reclaim_worker(0, job=1)  # job1's worker 0 dies
    stats = dp.job_stats
    assert stats[0]["reclaimed"] == 0 and stats[1]["reclaimed"] == 1
    # job0's window survives: worker 1 completes the full 2-worker sum
    r, res, _ = dp.ingest_batch([1], [0], 3 * p, jobs=[0], now=1)
    assert r[0]
    np.testing.assert_allclose(np.asarray(res)[0], 4.0)  # 1 + 3
    # job1's slot was reset and its dead worker waived: the survivor's
    # retransmission re-claims and completes as a live-worker sum
    r, res, _ = dp.ingest_batch([1], [0], 5 * p, jobs=[1], now=1)
    assert r[0]
    np.testing.assert_allclose(np.asarray(res)[0], 5.0)  # dead 2.0 dropped


def test_job_stats_sum_to_switch_stats():
    cfg = ss.DataplaneConfig(num_workers=9, num_slots=8, elems_per_packet=64,
                             num_jobs=3, job_workers=(4, 4, 1))
    dp = ss.NumpyDataplane(cfg)
    ss.run_multitenant(dp, [_vec(4, 1024, 1), _vec(4, 1024, 2),
                            _vec(1, 256, 3)], drop_prob=0.1, seed=9)
    total, per_job = dp.stats, dp.job_stats
    for name in COUNTERS:
        assert total[name] == sum(s[name] for s in per_job)


# ---------------------------------------------------------------------------
# query stream + training job sharing one switch
# ---------------------------------------------------------------------------


def test_query_stream_shares_switch_with_training_job():
    rng = np.random.default_rng(42)
    keys = rng.integers(0, 16, size=20_000)
    values = (rng.standard_normal(20_000) * 3).astype(np.float32)
    gb = Q.StreamedGroupBySum(num_groups=16, elems_per_packet=64)
    qvec = gb.vectors(keys, values, batch=2048)
    train = _vec(4, 2048, seed=8)
    cfg = ss.DataplaneConfig(num_workers=5, num_slots=8, elems_per_packet=64,
                             num_jobs=2, job_workers=(4, 1),
                             job_priorities=(1, 0))
    (tflat, qflat), rep = ss.run_multitenant(
        ss.NumpyDataplane(cfg), [train, qvec], drop_prob=0.1, seed=4)
    got = gb.finalize(qflat)
    want = Q.spark_like_groupby(keys, values)
    assert got.keys() == want.keys()
    for k in want:
        assert got[k] == pytest.approx(want[k], rel=1e-4)
    ref = train.astype(np.float64).sum(0)
    assert np.max(np.abs(np.asarray(tflat, np.float64) - ref)) < 0.1
    assert all(d is not None for d in rep["done_round"])


# ---------------------------------------------------------------------------
# shared-dataplane registry + switch_emu wiring
# ---------------------------------------------------------------------------


def test_shared_dataplane_registry_create_validate_reset():
    ss.reset_shared_dataplanes()
    try:
        cfg = ss.DataplaneConfig(num_workers=2, num_slots=4,
                                 num_jobs=2, job_workers=(2, 2))
        dp = ss.shared_dataplane("t0", cfg)
        assert ss.shared_dataplane("t0", cfg) is dp
        other = ss.DataplaneConfig(num_workers=3, num_slots=4,
                                   num_jobs=2, job_workers=(3, 3))
        with pytest.raises(ValueError, match="mismatched"):
            ss.shared_dataplane("t0", other)
    finally:
        ss.reset_shared_dataplanes()


def test_switch_emu_aggregators_share_one_dataplane():
    """Two training jobs' switch_emu aggregators (different ``switch_job``)
    plus direct query traffic ride one named dataplane; the aggregated bits
    are identical to the non-shared single-tenant switch_emu path."""
    import jax

    from repro.core.agg import AggConfig, Aggregator

    ss.reset_shared_dataplanes()
    try:
        mesh = compat.make_mesh((1,), ("data",))
        x0 = jnp.asarray(_vec(1, 600, seed=10)[0])
        x1 = jnp.asarray(_vec(1, 600, seed=11)[0])
        base = Aggregator(AggConfig(strategy="switch_emu"), ("data",))
        ref = jax.jit(compat.shard_map(base.allreduce, mesh=mesh,
                                       in_specs=P(), out_specs=P(),
                                       check_vma=False))
        want0, want1 = ref(x0), ref(x1)
        outs = []
        for job, x in ((0, x0), (1, x1)):
            agg = Aggregator(AggConfig(strategy="switch_emu",
                                       switch_shared="shared-test",
                                       switch_jobs=2, switch_job=job),
                             ("data",))
            outs.append(jax.jit(compat.shard_map(
                agg.allreduce, mesh=mesh, in_specs=P(), out_specs=P(),
                check_vma=False))(x))
        # bits unchanged by tenancy: the lossless fabric delivers every
        # result however admission interleaves the claims
        np.testing.assert_array_equal(_bits(outs[0]), _bits(want0))
        np.testing.assert_array_equal(_bits(outs[1]), _bits(want1))
        entry_dp = ss.shared_dataplane(
            "shared-test",
            ss.DataplaneConfig(num_workers=1, num_slots=8,
                               elems_per_packet=256, fmt_name="fp32",
                               variant="fpisa_a", num_jobs=2,
                               job_workers=(1, 1)))
        per_job = entry_dp.job_stats
        assert per_job[0]["packets"] > 0 and per_job[1]["packets"] > 0
    finally:
        ss.reset_shared_dataplanes()


def test_switch_job_out_of_range_rejected():
    from repro.core.agg import AggConfig

    with pytest.raises(ValueError, match="switch_job"):
        AggConfig(strategy="switch_emu", switch_shared="x",
                  switch_jobs=2, switch_job=2)


# ---------------------------------------------------------------------------
# fairness metric
# ---------------------------------------------------------------------------


def test_jain_fairness_bounds():
    assert ss.jain_fairness([5.0, 5.0, 5.0]) == pytest.approx(1.0)
    assert ss.jain_fairness([1.0, 0.0, 0.0]) == pytest.approx(1 / 3)
    assert 0.5 < ss.jain_fairness([2.0, 1.0]) < 1.0
