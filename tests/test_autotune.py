"""Cost-model autotuner contract (src/repro/autotune, DESIGN.md §13):
the affine fit recovers planted coefficients from synthetic traces, the
bucket-plan search picks a planted optimum, ``--bucket-bytes auto``
resolves through ``AggConfig.from_args`` (and falls back LOUDLY with no
trace), and the tuned plan stays bit-identical to the default — tuning
may only ever change the schedule, never the bits."""
import argparse
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.autotune import costmodel, profile, search
from repro.core.agg import AggConfig, add_agg_args
from repro.trace import export

# planted model: cheap fixed cost, collective dominated by per-element wire
# time -> overlapping it with encode/finish pays, so an INTERIOR bucket size
# beats both per-leaf (fixed cost x many buckets) and one giant bucket (no
# overlap).  All costs exact-affine, so the fit must recover them exactly.
PLANTED = {
    "encode": costmodel.PhaseCost(a=5e-6, b=4e-9),
    "collective": costmodel.PhaseCost(a=5e-6, b=10e-9),
    "finish": costmodel.PhaseCost(a=5e-6, b=4e-9),
}


def planted_spans(sizes=(1024, 4096, 16384, 65536), reps=2):
    spans = []
    for n in sizes:
        for phase, cost in PLANTED.items():
            for _ in range(reps):
                spans.append({
                    "name": "autotune.probe", "id": len(spans), "parent": -1,
                    "depth": 0, "tid": 0, "ts": 0.0, "dur": cost(n),
                    "synced": True,
                    "tags": {"phase": phase, "elems": n},
                })
    return spans


def write_trace(path, spans):
    with open(path, "w") as f:
        f.write(json.dumps(export.header()) + "\n")
        for sp in spans:
            f.write(json.dumps(sp) + "\n")
    return str(path)


# leaves totalling 256 KiB: 64 x 1024-elem f32 -> candidates
# (0, 64KiB, 128KiB, 256KiB); under PLANTED the 64 KiB cut wins
LEAVES = [jax.ShapeDtypeStruct((1024,), jnp.float32) for _ in range(64)]
PLANTED_BEST = 64 << 10


# ---------------------------------------------------------------------------
# fit
# ---------------------------------------------------------------------------


def test_fit_recovers_planted_coefficients():
    model = costmodel.fit(planted_spans())
    for phase, cost in PLANTED.items():
        got = model.phases[phase]
        assert got.a == pytest.approx(cost.a, rel=1e-6)
        assert got.b == pytest.approx(cost.b, rel=1e-6)
        assert model.samples[phase] == 8


def test_fit_rejects_single_size_and_unsynced():
    with pytest.raises(ValueError, match="2 distinct"):
        costmodel.fit(planted_spans(sizes=(4096,)))
    spans = planted_spans()
    for sp in spans:
        sp["synced"] = False
    with pytest.raises(ValueError, match="2 distinct"):
        costmodel.fit(spans)


def test_fit_clamps_negative_coefficients():
    spans = [{"name": "p", "id": i, "parent": -1, "depth": 0, "tid": 0,
              "ts": 0.0, "dur": d, "synced": True,
              "tags": {"phase": ph, "elems": n}}
             for i, (ph, n, d) in enumerate(
                 # decreasing time with size -> raw slope negative
                 [(ph, n, 1e-3 / k) for ph in costmodel.PHASES
                  for k, n in enumerate((256, 4096), start=1)])]
    model = costmodel.fit(spans)
    for ph in costmodel.PHASES:
        assert model.phases[ph].b == 0.0


def test_pipeline_time_recurrence():
    model = costmodel.CostModel(phases=PLANTED)
    enc, col, fin = (PLANTED["encode"], PLANTED["collective"],
                     PLANTED["finish"])
    sizes = [1000, 2000, 3000]
    expect = enc(1000)
    expect += max(col(1000), enc(2000))
    expect += max(col(2000), enc(3000) + fin(1000))
    expect += max(col(3000), fin(2000))
    expect += fin(3000)
    assert model.pipeline_time(sizes) == pytest.approx(expect)
    assert model.pipeline_time([]) == 0.0


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------


def test_candidates_sweep_and_dedup():
    cands = search.candidate_bucket_bytes(256 << 10)
    assert cands[0] == 0
    assert (64 << 10) in cands and (256 << 10) in cands
    assert len(set(cands)) == len(cands)
    # workload smaller than lo: just (0, lo)
    assert search.candidate_bucket_bytes(1000) == (0, 1 << 16)


def test_plan_sizes_per_leaf_pads_and_skips_non_float():
    leaves = [jax.ShapeDtypeStruct((777,), jnp.float32),
              jax.ShapeDtypeStruct((8,), jnp.int32),
              jax.ShapeDtypeStruct((512,), jnp.float32)]
    sizes = search.plan_sizes(leaves, block=256, bucket_bytes=0)
    # reverse-flatten dispatch order, block-padded, ints dropped
    assert sizes == [512, 1024]


def test_search_picks_planted_optimum():
    model = costmodel.fit(planted_spans())
    best, scores = search.choose_bucket_bytes(model, LEAVES, block=256)
    assert best == PLANTED_BEST
    assert scores[best] == min(scores.values())
    assert set(scores) == {0, 64 << 10, 128 << 10, 256 << 10}


def test_auto_from_trace_file(tmp_path):
    path = write_trace(tmp_path / "t.jsonl", planted_spans())
    got = search.auto_bucket_bytes(trace_path=path, block=256, leaves=LEAVES)
    assert got == PLANTED_BEST


def test_auto_env_var(tmp_path, monkeypatch):
    path = write_trace(tmp_path / "t.jsonl", planted_spans())
    monkeypatch.setenv(search.TRACE_ENV, path)
    got = search.auto_bucket_bytes(block=256, leaves=LEAVES)
    assert got == PLANTED_BEST


def test_auto_without_trace_falls_back_loudly(tmp_path, monkeypatch):
    monkeypatch.delenv(search.TRACE_ENV, raising=False)
    with pytest.warns(UserWarning, match="falling back"):
        got = search.auto_bucket_bytes()
    assert got == search.DEFAULT_AUTO_BUCKET_BYTES
    with pytest.warns(UserWarning, match="missing file"):
        got = search.auto_bucket_bytes(trace_path=str(tmp_path / "no.jsonl"))
    assert got == search.DEFAULT_AUTO_BUCKET_BYTES


# ---------------------------------------------------------------------------
# --bucket-bytes auto through the shared CLI surface
# ---------------------------------------------------------------------------


def _parse(argv):
    ap = argparse.ArgumentParser()
    add_agg_args(ap)
    return ap.parse_args(argv)


def test_from_args_plain_int_unchanged():
    cfg = AggConfig.from_args(_parse(["--bucket-bytes", "4096"]))
    assert cfg.bucket_bytes == 4096


def test_from_args_auto_with_trace(tmp_path, monkeypatch):
    monkeypatch.delenv(search.TRACE_ENV, raising=False)
    path = write_trace(tmp_path / "t.jsonl", planted_spans())
    cfg = AggConfig.from_args(_parse(
        ["--bucket-bytes", "auto", "--autotune-trace", path]))
    # resolved against the synthetic reference workload: a concrete plan,
    # never the sentinel
    assert isinstance(cfg.bucket_bytes, int) and cfg.bucket_bytes >= 0


def test_from_args_auto_without_trace_warns(monkeypatch):
    monkeypatch.delenv(search.TRACE_ENV, raising=False)
    with pytest.warns(UserWarning, match="falling back"):
        cfg = AggConfig.from_args(_parse(["--bucket-bytes", "auto"]))
    assert cfg.bucket_bytes == search.DEFAULT_AUTO_BUCKET_BYTES


def test_bucket_bytes_flag_rejects_garbage():
    with pytest.raises(SystemExit):
        _parse(["--bucket-bytes", "lots"])


# ---------------------------------------------------------------------------
# replay profiler end-to-end + the tuning-never-changes-bits contract
# ---------------------------------------------------------------------------


def test_profile_phases_feed_the_fit():
    cfg = AggConfig(strategy="fpisa", backend="jnp")
    spans = profile.profile_phases(cfg, sizes=(256, 1024), iters=2, warmup=1)
    assert len(spans) == 2 * 2 * 3
    assert all(sp["synced"] for sp in spans)
    model = costmodel.fit(spans)
    assert set(model.phases) == set(costmodel.PHASES)
    for ph in costmodel.PHASES:  # real measurements: nonneg, finite
        c = model.phases[ph]
        assert c.a >= 0 and c.b >= 0 and np.isfinite(c.a + c.b)


def test_profile_rejects_non_split_phase_strategy():
    with pytest.raises(ValueError, match="split-phase"):
        profile.profile_phases(AggConfig(strategy="native", backend="jnp"),
                               sizes=(256,))


def test_tuned_plan_is_bit_identical_to_default(tmp_path):
    """Whatever the tuner picks, the result bits match the default plan —
    the bucketer parity contract the search relies on."""
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core.agg import Aggregator

    rng = np.random.default_rng(3)
    tree = {f"l{i}": jnp.asarray((rng.standard_normal(n) * 0.01)
                                 .astype(np.float32))
            for i, n in enumerate((2048, 777, 4096, 13))}
    path = write_trace(tmp_path / "t.jsonl", planted_spans())
    tuned = search.auto_bucket_bytes(
        trace_path=path, block=256,
        leaves=[jax.ShapeDtypeStruct(v.shape, v.dtype)
                for v in tree.values()])
    mesh = compat.make_mesh((jax.device_count(),), ("data",))

    def run(bucket_bytes):
        agg = Aggregator(AggConfig(strategy="fpisa", backend="jnp",
                                   bucket_bytes=bucket_bytes), ("data",))
        return jax.jit(compat.shard_map(
            agg.allreduce_tree, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), tree),),
            out_specs=jax.tree.map(lambda _: P(), tree),
            check_vma=False))(tree)

    a, b = run(0), run(tuned)
    for k in tree:
        assert jnp.all(a[k].view(jnp.int32) == b[k].view(jnp.int32)), k
