"""Paper Fig. 9 gate: FPISA-A gradient aggregation must not change training
convergence. A small LM is trained with exact float aggregation vs the
bit-faithful sequential FPISA-A emulation over 4 simulated workers; final
losses must track closely (the paper reports <0.1% accuracy delta)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import fpisa as F
from repro.models.registry import build
from repro.optim import optimizers


WORKERS = 4
STEPS = 30


def _make(seed=0):
    cfg = get_smoke_config("qwen1.5-0.5b").with_(num_layers=2, d_model=64)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def _worker_batches(cfg, step):
    ks = jax.random.PRNGKey(1000 + step)
    toks = jax.random.randint(ks, (WORKERS, 2, 32), 0, cfg.vocab_size)
    # repeated motif -> learnable structure
    motif = jax.random.randint(jax.random.PRNGKey(5), (1, 1, 8), 0, cfg.vocab_size)
    toks = toks.at[:, :, :8].set(jnp.broadcast_to(motif, (WORKERS, 2, 8)))
    toks = toks.at[:, :, 16:24].set(jnp.broadcast_to(motif, (WORKERS, 2, 8)))
    return toks


def _train(aggregate, seed=0):
    cfg, model, params = _make(seed)
    opt_cfg = optimizers.OptConfig(name="adamw", lr=3e-3, warmup_steps=5)
    opt = optimizers.init(params, opt_cfg)

    grad_fn = jax.jit(jax.value_and_grad(model.loss))
    losses = []
    for step in range(STEPS):
        toks = _worker_batches(cfg, step)
        worker_grads = []
        worker_losses = []
        for w in range(WORKERS):
            l, g = grad_fn(params, {"tokens": toks[w]})
            worker_grads.append(g)
            worker_losses.append(float(l))
        grads = aggregate(worker_grads)
        params, opt, _ = optimizers.update(params, grads, opt, opt_cfg)
        losses.append(float(np.mean(worker_losses)))
    return losses


def _agg_exact(worker_grads):
    return jax.tree.map(lambda *gs: sum(gs) / WORKERS, *worker_grads)


def _agg_fpisa_a(worker_grads):
    def one(*gs):
        stacked = jnp.stack([g.reshape(-1) for g in gs]).astype(jnp.float32)
        out = F.fpisa_sum_sequential(stacked, variant="fpisa_a")
        return (out / WORKERS).reshape(gs[0].shape).astype(gs[0].dtype)

    return jax.tree.map(one, *worker_grads)


@pytest.mark.slow
def test_fpisa_a_training_matches_exact():
    exact = _train(_agg_exact)
    fpisa = _train(_agg_fpisa_a)
    assert exact[-1] < exact[0] * 0.9, f"baseline didn't learn: {exact}"
    assert fpisa[-1] < fpisa[0] * 0.9, f"fpisa didn't learn: {fpisa}"
    # convergence curves must track each other (paper Fig. 9)
    diffs = [abs(a - b) / max(abs(a), 1e-6) for a, b in zip(exact, fpisa)]
    assert np.mean(diffs[-10:]) < 0.05, (exact[-5:], fpisa[-5:])
