#!/usr/bin/env bash
# Canonical test entry point — run from the repo root or tests/:
#   bash tests/run.sh                 # whole suite (the tier-1 command)
#   bash tests/run.sh tests/test_fpisa.py -k roundtrip
#
# Exports the same environment the CI / tier-1 gate uses so multi-device
# shard_map tests and local runs behave identically everywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

# https://github.com/tensorflow/tensorflow/blob/master/tensorflow/compiler/xla/xla.proto
# 8 host devices so shard_map collectives are exercised without TPUs. The
# in-process tests keep seeing 1 logical problem per device; the heavy
# multi-device cases still run in subprocesses (tests/conftest.py), which
# inherit and re-export the same flag.
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
export PYTHONPATH="$PWD/src${PYTHONPATH:+:$PYTHONPATH}"
export TF_CPP_MIN_LOG_LEVEL=${TF_CPP_MIN_LOG_LEVEL:-4}  # silence XLA chatter

# invariant linter first (stdlib-only, ~1s): a lint violation fails the
# suite before pytest spends minutes compiling jits. SKIP_LINT=1 opts out
# (e.g. when bisecting a runtime failure through known-unclean trees).
if [[ "${SKIP_LINT:-0}" != "1" ]]; then
  /usr/bin/env python3 -m tools.repro_lint src tests benchmarks examples
fi

/usr/bin/env python3 -m pytest -x -q "$@"
