"""Backend parity: allreduce(backend="pallas") must be BIT-identical to
backend="jnp" for every strategy x wire_bits x chunk_elems combination, on
both the flat (single-axis) and hierarchical (pod,data) reduction paths,
including edge cases (all-zero gradients, denormal flush, NaN/Inf clamping).

Runs under shard_map on an 8-device host mesh (subprocess — this process
keeps 1 device per the project brief)."""
import pytest


PARITY_CODE = r"""
import numpy as np, jax
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import allreduce as AR

mesh_flat = compat.make_mesh((8,), ("data",))
mesh_hier = compat.make_mesh((2, 4), ("pod", "data"))
x = (np.random.default_rng(0).standard_normal((8, 3000)) * 0.01).astype(np.float32)

def run(cfg, hier):
    mesh = mesh_hier if hier else mesh_flat
    axes = ("pod", "data") if hier else ("data",)
    spec = P(axes if hier else "data")
    fn = jax.jit(compat.shard_map(lambda xs: AR.allreduce(xs[0], axes, cfg),
                                  mesh=mesh, in_specs=spec, out_specs=P(),
                                  check_vma=False))
    return np.asarray(fn(x.reshape(8, 1, 3000)))

# fpisa differs by backend on both reduction paths: full sweep
for hier in (False, True):
    for wire in (32, 16, 8):
        for chunk in (0, 2048):
            a = run(AR.AggConfig(strategy="fpisa", wire_bits=wire,
                                 chunk_elems=chunk, backend="jnp"), hier)
            b = run(AR.AggConfig(strategy="fpisa", wire_bits=wire,
                                 chunk_elems=chunk, backend="pallas"), hier)
            assert np.array_equal(a.view(np.int32), b.view(np.int32)), \
                ("fpisa", hier, wire, chunk)

# remaining strategies route around the transform backend — parity must
# still hold (trivially) so backend="pallas" is safe fleet-wide
for strat in ("native", "switchml", "fpisa_seq"):
    for chunk in (0, 2048):
        a = run(AR.AggConfig(strategy=strat, chunk_elems=chunk, backend="jnp"), True)
        b = run(AR.AggConfig(strategy=strat, chunk_elems=chunk, backend="pallas"), True)
        assert np.array_equal(a.view(np.int32), b.view(np.int32)), (strat, chunk)
print("PARITY_OK")
"""


EDGE_CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import allreduce as AR

mesh = compat.make_mesh((2, 4), ("pod", "data"))

def run(cfg, x, axes=("pod", "data")):
    fn = jax.jit(compat.shard_map(lambda xs: AR.allreduce(xs[0], axes, cfg),
                                  mesh=mesh, in_specs=P(("pod", "data")),
                                  out_specs=P(), check_vma=False))
    return np.asarray(fn(x.reshape(8, 1, -1)))

cases = {
    # all-zero gradients: bmax pmax sees exp=0 everywhere, decode must give 0
    "zeros": np.zeros((8, 2000), np.float32),
    # denormals flush to zero inside encode on every worker
    "denormal": np.full((8, 2000), 1e-42, np.float32),
    # NaN/Inf clamp to max finite per fpisa.encode (documented deviation);
    # the SUM may still overflow back to inf at renormalize, but never NaN
    "special": np.where(np.arange(16000).reshape(8, 2000) % 7 == 0,
                        np.inf, 1.0).astype(np.float32),
}
cases["special"][0, :5] = np.nan

for name, x in cases.items():
    for chunk in (0, 512):
        a = run(AR.AggConfig(strategy="fpisa", chunk_elems=chunk, backend="jnp"), x)
        b = run(AR.AggConfig(strategy="fpisa", chunk_elems=chunk, backend="pallas"), x)
        assert np.array_equal(a.view(np.int32), b.view(np.int32)), (name, chunk)
        if name == "zeros":
            assert not a.any(), "all-zero input must aggregate to exact zero"
        if name == "denormal":
            assert not a.any(), "denormals must flush to zero"
        if name == "special":
            assert not np.isnan(a).any(), "NaN must be clamped out by encode"
print("EDGE_OK")
"""


TRAIN_PALLAS_CODE = r"""
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
from repro.configs import get_smoke_config
from repro.models.registry import build
from repro.core.allreduce import AggConfig
from repro.optim import optimizers
from repro.sharding import rules
from repro.train.step import make_train_step
from repro.data.pipeline import SyntheticCorpus, ShardedLoader

# fully-manual (pod, data) mesh: the aggregation backend is orthogonal to TP,
# and old-jax XLA cannot host interpret-mode pallas calls inside a PARTIALLY
# manual shard_map (manual replica axes + auto 'model' trips an XLA
# IsManualSubgroup check). On TPU the kernels compile to Mosaic and the
# partial-manual mesh works; CPU CI exercises the pure-DP shape.
mesh = compat.make_mesh((2, 4), ("pod", "data"))
cfg = get_smoke_config("internlm2-20b").with_(num_kv_heads=2, num_heads=8)
model = build(cfg)
params0 = model.init(jax.random.PRNGKey(0))
pspecs = rules.param_pspecs(params0, cfg, mesh)
opt_cfg = optimizers.OptConfig(name="adamw", lr=1e-3, warmup_steps=5)
ospecs = rules.opt_pspecs(pspecs, params0, mesh)
GB = 8
loader = ShardedLoader(SyntheticCorpus(cfg.vocab_size), GB, 64)
losses = {}
for backend in ["jnp", "pallas"]:
    params = jax.device_put(params0, rules.named(mesh, pspecs))
    opt = optimizers.init(params, opt_cfg)
    opt = optimizers.OptState(step=jax.device_put(opt.step, NamedSharding(mesh, P())),
                              m=jax.device_put(opt.m, rules.named(mesh, ospecs)),
                              v=jax.device_put(opt.v, rules.named(mesh, ospecs)))
    agg = AggConfig(strategy="fpisa", backend=backend)
    step = jax.jit(make_train_step(model, mesh, agg, opt_cfg, GB))
    ls = []
    for i in range(3):
        batch = {"tokens": jax.device_put(loader.batch_at(i)["tokens"],
                                          NamedSharding(mesh, P(("pod","data"), None)))}
        params, opt, m = step(params, opt, batch)
        ls.append(float(m["loss"]))
    losses[backend] = ls
# the fused-kernel backend is bit-identical, so the training trajectories
# must agree exactly — not just approximately
assert losses["pallas"] == losses["jnp"], losses
assert losses["pallas"][-1] < losses["pallas"][0], losses
print("TRAIN_PALLAS_OK")
"""


def test_backend_parity_all_strategies(multi_device_runner):
    out = multi_device_runner(PARITY_CODE, n_devices=8, timeout=900)
    assert "PARITY_OK" in out


def test_backend_parity_edge_cases(multi_device_runner):
    out = multi_device_runner(EDGE_CODE, n_devices=8, timeout=600)
    assert "EDGE_OK" in out


def test_train_step_pallas_backend(multi_device_runner):
    out = multi_device_runner(TRAIN_PALLAS_CODE, n_devices=8, timeout=900)
    assert "TRAIN_PALLAS_OK" in out
